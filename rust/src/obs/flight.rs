//! Always-on flight recorder: the last N events, dumped on failure.
//!
//! The event log ([`crate::obs::log`]) is opt-in and unbounded-ish; the
//! flight recorder is the opposite trade — always on, fixed size, and
//! read only after something went wrong. Critical sites [`note`] their
//! rendered event lines into a fixed ring of slots; writers claim a slot
//! with one `fetch_add` and skip (counting a drop) rather than block if
//! a slot is contended, so the hot path never takes a blocking lock and
//! never allocates beyond the line itself.
//!
//! A [`dump`] writes the ring to `<dir>/flight/<reason>-<pid>.jsonl`
//! (header line first, then the retained events, oldest first). Dumps
//! fire on panic ([`install_panic_hook`]), on an overload-shed burst in
//! the serve engine, and when a campaign worker bails mid-shard — the
//! exact paths the fleet's chaos tests exercise, which is what makes
//! post-mortems of killed workers possible at all. `occamy trace
//! flight` renders a dump back ([`render_dump`]).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock, PoisonError};

use crate::runtime::json::Json;

/// Events retained (the "last N"). Small on purpose: a dump is a tail,
/// not a log.
pub const CAPACITY: usize = 256;

struct Recorder {
    slots: Vec<Mutex<Option<String>>>,
    /// Next slot to claim (monotonic; slot index is `head % CAPACITY`).
    head: AtomicUsize,
    noted: AtomicU64,
    dropped: AtomicU64,
    dump_dir: Mutex<Option<PathBuf>>,
}

fn recorder() -> &'static Recorder {
    static R: OnceLock<Recorder> = OnceLock::new();
    R.get_or_init(|| Recorder {
        slots: (0..CAPACITY).map(|_| Mutex::new(None)).collect(),
        head: AtomicUsize::new(0),
        noted: AtomicU64::new(0),
        dropped: AtomicU64::new(0),
        dump_dir: Mutex::new(None),
    })
}

/// Record one event line (no trailing newline). Never blocks: a slot
/// still being written by another thread is skipped and counted in the
/// dump header's `dropped`.
pub fn note(line: &str) {
    let r = recorder();
    // ordering: Relaxed — the fetch_add's RMW atomicity alone hands each
    // writer a distinct slot index; the line itself is published through
    // the slot Mutex, so the head carries no payload to synchronize.
    let i = r.head.fetch_add(1, Ordering::Relaxed) % CAPACITY;
    r.noted.fetch_add(1, Ordering::Relaxed);
    match r.slots[i].try_lock() {
        Ok(mut slot) => *slot = Some(line.to_string()),
        Err(_) => {
            // ordering: Relaxed — diagnostic tally for the dump header.
            r.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Where dumps land (`<dir>/<reason>-<pid>.jsonl`); callers pass
/// `<store>/flight`. Last set wins; no dump is written until set.
pub fn set_dump_dir(dir: &Path) {
    let r = recorder();
    *r.dump_dir.lock().unwrap_or_else(PoisonError::into_inner) = Some(dir.to_path_buf());
}

/// Install a panic hook that dumps the ring (reason `panic`) before the
/// previous hook runs. Idempotent.
pub fn install_panic_hook() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let _ = dump("panic");
            prev(info);
        }));
    });
}

/// Write the ring to `<dump dir>/<reason>-<pid>.jsonl`: one JSON header
/// line (`{"capacity":..,"dropped":..,"flight":"<reason>","noted":..}`)
/// followed by the retained lines, oldest first. Returns the path, or
/// `None` when no dump dir is set or the write fails — a failing dump
/// must never take the workload down with it.
pub fn dump(reason: &str) -> Option<PathBuf> {
    let r = recorder();
    let dir = r.dump_dir.lock().unwrap_or_else(PoisonError::into_inner).clone()?;
    std::fs::create_dir_all(&dir).ok()?;
    let path = dir.join(format!("{reason}-{}.jsonl", std::process::id()));
    let mut out = String::new();
    // ordering: Relaxed — best-effort counter snapshot for the header;
    // a dump racing live writers is inherently approximate.
    let dropped = r.dropped.load(Ordering::Relaxed);
    let noted = r.noted.load(Ordering::Relaxed);
    out.push_str(&format!(
        "{{\"capacity\":{CAPACITY},\"dropped\":{dropped},\"flight\":{},\"noted\":{noted}}}\n",
        Json::Str(reason.to_string()),
    ));
    for line in snapshot() {
        out.push_str(&line);
        out.push('\n');
    }
    std::fs::write(&path, out).ok()?;
    Some(path)
}

/// The retained lines, oldest first.
pub fn snapshot() -> Vec<String> {
    let r = recorder();
    // ordering: Relaxed — head only picks the oldest-first walk order;
    // the lines themselves are read under each slot's Mutex.
    let head = r.head.load(Ordering::Relaxed);
    let mut out = Vec::new();
    for k in 0..CAPACITY {
        let i = (head + k) % CAPACITY;
        if let Ok(slot) = r.slots[i].try_lock() {
            if let Some(line) = slot.as_ref() {
                out.push(line.clone());
            }
        }
    }
    out
}

/// Render one dump file for `occamy trace flight`: the header summary
/// plus every retained line.
pub fn render_dump(path: &Path) -> anyhow::Result<String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read flight dump {}: {e}", path.display()))?;
    let mut lines = text.lines();
    let header = lines.next().ok_or_else(|| anyhow::anyhow!("{}: empty dump", path.display()))?;
    let h = Json::parse(header)
        .map_err(|e| anyhow::anyhow!("{}: bad dump header: {e}", path.display()))?;
    let reason = h
        .get("flight")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("{}: header has no \"flight\" reason", path.display()))?
        .to_string();
    let noted = h.get("noted").and_then(Json::as_u64).unwrap_or(0);
    let dropped = h.get("dropped").and_then(Json::as_u64).unwrap_or(0);
    let capacity = h.get("capacity").and_then(Json::as_u64).unwrap_or(CAPACITY as u64);
    let body: Vec<&str> = lines.collect();
    let mut out = format!(
        "Flight dump {} — reason: {reason}\n{noted} event(s) noted, {} retained (capacity {capacity}), {dropped} contended write(s) dropped\n",
        path.display(),
        body.len(),
    );
    for l in &body {
        out.push_str("  ");
        out.push_str(l);
        out.push('\n');
    }
    Ok(out)
}

/// Render every `*.jsonl` dump under a directory (sorted by file name),
/// for `occamy trace flight --store ROOT`.
pub fn render_dir(dir: &Path) -> anyhow::Result<String> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("read flight dir {}: {e}", dir.display()))?;
    let mut names: Vec<PathBuf> = entries
        .filter_map(Result::ok)
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "jsonl"))
        .collect();
    names.sort();
    anyhow::ensure!(!names.is_empty(), "no flight dumps under {}", dir.display());
    let mut out = String::new();
    for p in names {
        out.push_str(&render_dump(&p)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The recorder is process-global, and tests in one binary share it:
    // assertions use distinctive markers and tolerate unrelated lines.
    #[test]
    fn dump_round_trips_through_render() {
        let dir = std::env::temp_dir()
            .join(format!("occamy-flight-test-{}", std::process::id()))
            .join("flight");
        let _ = std::fs::remove_dir_all(&dir);
        set_dump_dir(&dir);
        for i in 0..CAPACITY + 7 {
            note(&format!("{{\"event\":\"flight_test\",\"i\":{i}}}"));
        }
        let path = dump("unit").expect("dump dir is set");
        assert!(path.file_name().unwrap().to_string_lossy().starts_with("unit-"));
        let snap = snapshot();
        assert!(snap.len() <= CAPACITY);
        // The oldest marker lines were evicted by the wrap.
        assert!(!snap.iter().any(|l| l == "{\"event\":\"flight_test\",\"i\":0}"));
        assert!(snap.iter().any(|l| l.contains("flight_test")));
        let rendered = render_dump(&path).unwrap();
        assert!(rendered.contains("reason: unit"), "{rendered}");
        assert!(rendered.contains("flight_test"), "{rendered}");
        let all = render_dir(&dir).unwrap();
        assert!(all.contains("reason: unit"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn render_dump_rejects_garbage() {
        let p = std::env::temp_dir().join(format!("occamy-flight-bad-{}.jsonl", std::process::id()));
        std::fs::write(&p, "not json\n").unwrap();
        assert!(render_dump(&p).is_err());
        std::fs::write(&p, "{\"no_reason\":1}\n").unwrap();
        assert!(render_dump(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }
}
