//! Structured JSONL event log — leveled, ring-buffered, off by default.
//!
//! Every event is one JSON object on one line, rendered through
//! [`crate::runtime::json::Json`] so key order (BTreeMap) and number
//! formatting are deterministic. Two stamping domains keep golden tests
//! honest:
//!
//! * **Sim-domain** events ([`Event::sim`]) carry a `cycle` field on the
//!   virtual clock and nothing wall-dependent — the same request always
//!   produces byte-identical lines.
//! * **Wall-domain** events ([`Event::wall`]) — daemon and fleet
//!   lifecycle — carry `t_ms` (milliseconds since the Unix epoch).
//!
//! The process-wide sink is disabled until [`init`] installs an
//! [`EventLog`]; call sites guard their hot paths with [`enabled`], so
//! an un-configured run pays one atomic load per event site. The serve
//! daemon wires `--log FILE` (or the spec's `log` key) through
//! [`init_to_file`]; every other entry point honors the `OCCAMY_LOG`
//! environment variable via [`init_from_env`]. Logging is pure
//! observation: it never changes a simulation result.

use std::collections::{BTreeMap, VecDeque};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock, PoisonError};

use crate::runtime::json::Json;
use crate::sim::Time;

/// Event severity. Ordered: `Debug < Info < Warn`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug,
    Info,
    Warn,
}

impl Level {
    pub fn name(&self) -> &'static str {
        match self {
            Level::Debug => "debug",
            Level::Info => "info",
            Level::Warn => "warn",
        }
    }
}

/// One structured event, built fluently and rendered as a single JSON
/// line. `src` names the emitting subsystem (`"serve"`, `"store"`,
/// `"fleet"`, `"campaign"`), `event` the lifecycle step.
#[derive(Debug, Clone)]
pub struct Event {
    level: Level,
    src: &'static str,
    event: &'static str,
    cycle: Option<Time>,
    wall: bool,
    fields: BTreeMap<String, Json>,
}

impl Event {
    /// A sim-domain event stamped at `cycle` on the virtual clock.
    /// Deterministic bytes: no wall time, no pid, nothing run-dependent.
    pub fn sim(src: &'static str, event: &'static str, cycle: Time) -> Self {
        Self {
            level: Level::Info,
            src,
            event,
            cycle: Some(cycle),
            wall: false,
            fields: BTreeMap::new(),
        }
    }

    /// A wall-domain event (daemon/fleet lifecycle); `t_ms` is stamped
    /// at render time.
    pub fn wall(src: &'static str, event: &'static str) -> Self {
        Self {
            level: Level::Info,
            src,
            event,
            cycle: None,
            wall: true,
            fields: BTreeMap::new(),
        }
    }

    pub fn level(mut self, level: Level) -> Self {
        self.level = level;
        self
    }

    pub fn u64(mut self, key: &str, v: u64) -> Self {
        self.fields.insert(key.to_string(), Json::Num(v as f64));
        self
    }

    pub fn str(mut self, key: &str, v: &str) -> Self {
        self.fields.insert(key.to_string(), Json::Str(v.to_string()));
        self
    }

    /// Render to one JSON line (no trailing newline). Reserved keys
    /// (`event`, `src`, `level`, `cycle`, `t_ms`) win over same-named
    /// payload fields — the BTreeMap insert order below guarantees it.
    /// Public so the flight recorder and the loadgen `--record` sink can
    /// reuse the exact sink byte format without going through a log.
    pub fn render(&self) -> String {
        let mut obj = self.fields.clone();
        obj.insert("event".to_string(), Json::Str(self.event.to_string()));
        obj.insert("src".to_string(), Json::Str(self.src.to_string()));
        obj.insert("level".to_string(), Json::Str(self.level.name().to_string()));
        if let Some(c) = self.cycle {
            obj.insert("cycle".to_string(), Json::Num(c as f64));
        }
        if self.wall {
            let t_ms = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_millis() as u64)
                .unwrap_or(0);
            obj.insert("t_ms".to_string(), Json::Num(t_ms as f64));
        }
        Json::Obj(obj).to_string()
    }
}

/// Rendered lines kept in memory for inspection ([`EventLog::recent`]).
const RING_CAPACITY: usize = 4096;

struct Inner {
    ring: VecDeque<String>,
    file: Option<std::fs::File>,
    /// Write failures (full/readonly disk) — logging degrades, never
    /// fails the workload.
    write_errors: u64,
    /// Lines evicted from the ring because it was full. A non-zero
    /// count means `recent()` is a tail, not the whole story.
    dropped: u64,
}

/// A JSONL event sink: a bounded in-memory ring plus an optional file.
pub struct EventLog {
    min_level: Level,
    inner: Mutex<Inner>,
}

impl EventLog {
    /// Ring-buffer only (tests, embedding).
    pub fn in_memory() -> Self {
        Self {
            min_level: Level::Debug,
            inner: Mutex::new(Inner {
                ring: VecDeque::new(),
                file: None,
                write_errors: 0,
                dropped: 0,
            }),
        }
    }

    /// Ring buffer plus a freshly truncated JSONL file at `path`.
    pub fn to_file(path: &Path) -> anyhow::Result<Self> {
        let file = std::fs::File::create(path)
            .map_err(|e| anyhow::anyhow!("open event log {}: {e}", path.display()))?;
        let mut log = Self::in_memory();
        log.inner.get_mut().unwrap_or_else(PoisonError::into_inner).file = Some(file);
        Ok(log)
    }

    /// Drop events below `level`.
    pub fn with_min_level(mut self, level: Level) -> Self {
        self.min_level = level;
        self
    }

    pub fn emit(&self, ev: &Event) {
        if ev.level < self.min_level {
            return;
        }
        let line = ev.render();
        let mut inner = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        if inner.ring.len() == RING_CAPACITY {
            inner.ring.pop_front();
            inner.dropped += 1;
        }
        inner.ring.push_back(line.clone());
        if let Some(file) = inner.file.as_mut() {
            use std::io::Write;
            if writeln!(file, "{line}").is_err() {
                inner.write_errors += 1;
            }
        }
    }

    /// Snapshot of the ring (oldest first).
    pub fn recent(&self) -> Vec<String> {
        let inner = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        inner.ring.iter().cloned().collect()
    }

    pub fn write_errors(&self) -> u64 {
        let inner = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        inner.write_errors
    }

    /// Lines evicted from the ring so far (the file sink, when present,
    /// still has them — only `recent()` forgets).
    pub fn dropped(&self) -> u64 {
        let inner = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        inner.dropped
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static GLOBAL: OnceLock<EventLog> = OnceLock::new();

/// Install `log` as the process-wide sink. Returns `false` (and drops
/// `log`) if a sink is already installed — first init wins.
pub fn init(log: EventLog) -> bool {
    let installed = GLOBAL.set(log).is_ok();
    if installed {
        // ordering: Release — pairs with the Acquire load in `enabled`;
        // a thread that observes the flag must also observe the fully
        // initialized GLOBAL sink it gates. (OnceLock::get synchronizes
        // too, so this is belt-and-braces, but the pairing keeps the
        // fast-path flag self-sufficient.)
        ENABLED.store(true, Ordering::Release);
    }
    installed
}

/// Install a file-backed sink at `path` (`--log FILE`, the serve spec's
/// `log` key).
pub fn init_to_file(path: &Path) -> anyhow::Result<()> {
    if !init(EventLog::to_file(path)?) {
        eprintln!("obs: event log already initialized; {} ignored", path.display());
    }
    Ok(())
}

/// Install a file-backed sink from `OCCAMY_LOG`, if set. A no-op when
/// the variable is absent/empty or a sink is already installed.
pub fn init_from_env() -> anyhow::Result<()> {
    match std::env::var("OCCAMY_LOG") {
        Ok(path) if !path.is_empty() && GLOBAL.get().is_none() => {
            init_to_file(Path::new(&path))
        }
        _ => Ok(()),
    }
}

/// Fast-path check for call sites: one atomic load when logging is off.
pub fn enabled() -> bool {
    // ordering: Acquire — pairs with the Release store in `init`: seeing
    // `true` here happens-after the sink installation completed.
    ENABLED.load(Ordering::Acquire)
}

/// Emit through the process-wide sink; a no-op until [`init`].
pub fn emit(ev: &Event) {
    if enabled() {
        if let Some(log) = GLOBAL.get() {
            log.emit(ev);
        }
    }
}

/// Ring snapshot of the process-wide sink (empty when uninitialized).
pub fn recent() -> Vec<String> {
    GLOBAL.get().map(EventLog::recent).unwrap_or_default()
}

/// Ring evictions in the process-wide sink (0 when uninitialized).
/// Exported as `occamy_log_dropped_total` by the serve metrics verb.
pub fn dropped() -> u64 {
    GLOBAL.get().map(EventLog::dropped).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_events_render_deterministic_bytes() {
        let ev = Event::sim("serve", "accept", 1234)
            .u64("id", 7)
            .str("kernel", "axpy:1024");
        let a = ev.render();
        let b = ev.render();
        assert_eq!(a, b);
        assert_eq!(
            a,
            r#"{"cycle":1234,"event":"accept","id":7,"kernel":"axpy:1024","level":"info","src":"serve"}"#
        );
    }

    #[test]
    fn wall_events_carry_a_timestamp_and_sim_events_do_not() {
        let wall = Event::wall("fleet", "restart").str("shard", "1/2").render();
        assert!(wall.contains("\"t_ms\":"), "{wall}");
        let sim = Event::sim("serve", "dispatch", 9).render();
        assert!(!sim.contains("t_ms"), "{sim}");
        assert!(sim.contains("\"cycle\":9"), "{sim}");
    }

    #[test]
    fn hostile_field_values_stay_one_line_and_parse_back() {
        let ev = Event::sim("serve", "accept", 0).str("kernel", "evil\n\"name\"\t\u{1}");
        let line = ev.render();
        assert!(!line.contains('\n'), "{line}");
        let parsed = Json::parse(&line).unwrap();
        assert_eq!(parsed.get("kernel").unwrap().as_str(), Some("evil\n\"name\"\t\u{1}"));
    }

    #[test]
    fn ring_is_bounded_and_levels_filter() {
        let log = EventLog::in_memory().with_min_level(Level::Info);
        log.emit(&Event::sim("t", "dropped", 0).level(Level::Debug));
        assert!(log.recent().is_empty(), "debug filtered below Info");
        for i in 0..(RING_CAPACITY as u64 + 10) {
            log.emit(&Event::sim("t", "kept", i));
        }
        let lines = log.recent();
        assert_eq!(lines.len(), RING_CAPACITY);
        assert!(lines[0].contains("\"cycle\":10"), "oldest evicted: {}", lines[0]);
    }

    #[test]
    fn saturating_the_ring_counts_drops() {
        let log = EventLog::in_memory();
        assert_eq!(log.dropped(), 0);
        for i in 0..(RING_CAPACITY as u64) {
            log.emit(&Event::sim("t", "fill", i));
        }
        assert_eq!(log.dropped(), 0, "exactly full is not yet a drop");
        for i in 0..17u64 {
            log.emit(&Event::sim("t", "overflow", i));
        }
        assert_eq!(log.dropped(), 17);
        assert_eq!(log.recent().len(), RING_CAPACITY);
    }

    #[test]
    fn file_sink_appends_jsonl() {
        let path = std::env::temp_dir().join(format!(
            "occamy-obs-log-test-{}.jsonl",
            std::process::id()
        ));
        let log = EventLog::to_file(&path).unwrap();
        log.emit(&Event::sim("t", "one", 1));
        log.emit(&Event::wall("t", "two"));
        drop(log);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for l in lines {
            assert!(Json::parse(l).is_ok(), "not JSON: {l}");
        }
        let _ = std::fs::remove_file(&path);
    }
}
