//! Deterministic span model for end-to-end request tracing.
//!
//! A *span* is one named interval on a timeline, keyed by a 64-bit
//! trace id (one per request tree) and a 64-bit span id, with an
//! optional parent span — the classic distributed-tracing shape, minus
//! the wall-clock entropy. Every id here is derived by FNV-1a over
//! stable inputs (request key, admission sequence number, parent span
//! bytes), so the same seeded run produces byte-identical span records
//! on any machine: sim-domain spans ride [`Event::sim`] and carry only
//! virtual cycles.
//!
//! Propagation crosses process boundaries as a `traceparent` string,
//! `<trace:016x>-<span:016x>`: the serve protocol's `submit` carries it
//! per request, and `campaign run` workers inherit one from
//! `--trace-parent` or the `OCCAMY_TRACE_PARENT` environment variable
//! (the fleet scheduler sets both up, so every shard on every host
//! stitches under one fleet-run root span).
//!
//! Span records land in the [`crate::obs::log`] JSONL stream as
//! `src = "span"` events; [`SpanRecord::parse`] reads them back for
//! `occamy trace export --spans`, `occamy trace serve-report` and the
//! tree well-formedness checks ([`check_trees`]).

use std::sync::OnceLock;

use crate::runtime::json::Json;
use crate::sim::Time;

use super::log::Event;

/// Environment variable carrying an inherited trace context
/// (`--trace-parent` wins over it).
pub const ENV_TRACE_PARENT: &str = "OCCAMY_TRACE_PARENT";

/// FNV-1a 64-bit over a sequence of byte slices — the same hash the
/// campaign store uses for config fingerprints, so span ids inherit its
/// stability guarantees.
fn fnv1a64(parts: &[&[u8]]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for part in parts {
        for &b in *part {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// A (trace, span) pair: the identity a request carries across layer
/// boundaries. Rendered and parsed as `<trace:016x>-<span:016x>`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TraceContext {
    pub trace: u64,
    pub span: u64,
}

impl TraceContext {
    /// Deterministic root context for a named run (a loadgen seed, a
    /// fleet run id): the trace id hashes the key, the root span id
    /// hashes the trace.
    pub fn root(key: &str) -> TraceContext {
        let trace = fnv1a64(&[key.as_bytes()]);
        TraceContext {
            trace,
            span: fnv1a64(&[&trace.to_be_bytes(), b"root"]),
        }
    }

    /// A child context in the same trace, keyed by a stable name and a
    /// sequence number (e.g. request key + admission seq).
    pub fn child(&self, key: &str, seq: u64) -> TraceContext {
        TraceContext {
            trace: self.trace,
            span: derive_span(self.trace, key, seq),
        }
    }

    /// The wire form: `<trace:016x>-<span:016x>`.
    pub fn render(&self) -> String {
        format!("{:016x}-{:016x}", self.trace, self.span)
    }

    /// Parse the wire form back; `None` for anything else. The wire
    /// form is lowercase hex only (what [`TraceContext::render`]
    /// emits), so a strict round-trip is the contract.
    pub fn parse(s: &str) -> Option<TraceContext> {
        let (t, sp) = s.split_once('-')?;
        if t.len() != 16 || sp.len() != 16 {
            return None;
        }
        if s.bytes().any(|b| b.is_ascii_uppercase()) {
            return None;
        }
        Some(TraceContext {
            trace: u64::from_str_radix(t, 16).ok()?,
            span: u64::from_str_radix(sp, 16).ok()?,
        })
    }
}

/// Span id for (trace, key, seq) — no wall clock, no randomness.
pub fn derive_span(trace: u64, key: &str, seq: u64) -> u64 {
    fnv1a64(&[&trace.to_be_bytes(), key.as_bytes(), &seq.to_be_bytes()])
}

/// Span id of a named child of `parent` (e.g. the `queue` and `execute`
/// phases under a request span).
pub fn child_span(parent: u64, label: &str) -> u64 {
    fnv1a64(&[&parent.to_be_bytes(), label.as_bytes()])
}

/// A fresh per-request trace for submissions that carry no
/// `traceparent`: self-rooted, derived from the serving context (config
/// fingerprint), the request key, and the admission seq.
pub fn self_rooted(fingerprint: &str, key: &str, seq: u64) -> TraceContext {
    let trace = fnv1a64(&[fingerprint.as_bytes(), key.as_bytes(), &seq.to_be_bytes()]);
    TraceContext {
        trace,
        span: derive_span(trace, key, seq),
    }
}

static AMBIENT: OnceLock<Option<TraceContext>> = OnceLock::new();

/// Install the process-ambient trace context from an explicit
/// `--trace-parent` value, falling back to `OCCAMY_TRACE_PARENT`.
/// First install wins (like the event log); returns the context now in
/// effect. An unparseable value is ignored rather than fatal — tracing
/// must never fail a workload.
pub fn init_ambient(flag: Option<&str>) -> Option<TraceContext> {
    let parsed = flag.and_then(TraceContext::parse).or_else(|| {
        // audit:allow(entropy-in-sim) -- traceparent inheritance from the parent process; span ids derived from it stay deterministic
        std::env::var(ENV_TRACE_PARENT).ok().as_deref().and_then(TraceContext::parse)
    });
    let _ = AMBIENT.set(parsed);
    ambient()
}

/// The ambient trace context, if one was installed.
pub fn ambient() -> Option<TraceContext> {
    AMBIENT.get().copied().flatten()
}

fn hex(v: u64) -> String {
    format!("{v:016x}")
}

/// A sim-domain span event (`src = "span"`): deterministic bytes, start
/// stamped in virtual cycles, `dur` in cycles. Callers chain metadata
/// fields (`id`, `kernel`, ...) before emitting.
pub fn sim_span(
    name: &'static str,
    ctx: TraceContext,
    parent: Option<u64>,
    start: Time,
    dur: Time,
) -> Event {
    let mut ev = Event::sim("span", name, start)
        .str("trace", &hex(ctx.trace))
        .str("span", &hex(ctx.span))
        .u64("dur", dur);
    if let Some(p) = parent {
        ev = ev.str("parent", &hex(p));
    }
    ev
}

/// A wall-domain span event (fleet/campaign lifecycle): `t_ms`-stamped,
/// correlated by the same trace/span ids.
pub fn wall_span(name: &'static str, ctx: TraceContext, parent: Option<u64>) -> Event {
    let mut ev = Event::wall("span", name)
        .str("trace", &hex(ctx.trace))
        .str("span", &hex(ctx.span));
    if let Some(p) = parent {
        ev = ev.str("parent", &hex(p));
    }
    ev
}

/// One span read back from a JSONL line. Non-span lines (and span lines
/// missing ids) parse to `None` and are skipped by every consumer.
#[derive(Debug, Clone)]
pub struct SpanRecord {
    /// The span name (the event field: `request`, `queue`, `execute`,
    /// `client`, `shard`, ...).
    pub name: String,
    pub trace: u64,
    pub span: u64,
    pub parent: Option<u64>,
    /// Start cycle — `None` for wall-domain spans.
    pub cycle: Option<u64>,
    /// Duration in cycles (0 when absent).
    pub dur: u64,
    /// The whole parsed object, for metadata lookups.
    fields: Json,
}

impl SpanRecord {
    pub fn parse(line: &str) -> Option<SpanRecord> {
        let v = Json::parse(line).ok()?;
        if v.get("src")?.as_str()? != "span" {
            return None;
        }
        let name = v.get("event")?.as_str()?.to_string();
        let id = |k: &str| {
            v.get(k).and_then(Json::as_str).and_then(|s| u64::from_str_radix(s, 16).ok())
        };
        let rec = SpanRecord {
            name,
            trace: id("trace")?,
            span: id("span")?,
            parent: id("parent"),
            cycle: v.get("cycle").and_then(Json::as_u64),
            dur: v.get("dur").and_then(Json::as_u64).unwrap_or(0),
            fields: v,
        };
        Some(rec)
    }

    /// End cycle of a sim-domain span.
    pub fn end(&self) -> Option<u64> {
        self.cycle.map(|c| c + self.dur)
    }

    pub fn field_u64(&self, key: &str) -> Option<u64> {
        self.fields.get(key).and_then(Json::as_u64)
    }

    pub fn field_str(&self, key: &str) -> Option<&str> {
        self.fields.get(key).and_then(Json::as_str)
    }
}

/// Parse every span record out of a JSONL text; non-span lines are
/// skipped, so the input can be a full event log.
pub fn parse_log(text: &str) -> Vec<SpanRecord> {
    text.lines().filter_map(SpanRecord::parse).collect()
}

/// Check that a set of spans forms well-formed trees:
///
/// * span ids are unique within a trace,
/// * every referenced parent id exists in the same trace (no orphans),
/// * every trace has exactly one root (a span without a parent),
/// * a sim-domain child's interval lies within its parent's.
///
/// Used by the property tests over seeded serve bursts; `Err` carries
/// the first violation found.
pub fn check_trees(spans: &[SpanRecord]) -> Result<(), String> {
    use std::collections::BTreeMap;
    let mut by_id: BTreeMap<(u64, u64), &SpanRecord> = BTreeMap::new();
    for s in spans {
        if by_id.insert((s.trace, s.span), s).is_some() {
            return Err(format!(
                "duplicate span id {} in trace {}",
                hex(s.span),
                hex(s.trace)
            ));
        }
    }
    let mut roots: BTreeMap<u64, usize> = BTreeMap::new();
    for s in spans {
        match s.parent {
            None => *roots.entry(s.trace).or_default() += 1,
            Some(p) => {
                let Some(parent) = by_id.get(&(s.trace, p)) else {
                    return Err(format!(
                        "span {} ({}) names orphan parent {} in trace {}",
                        hex(s.span),
                        s.name,
                        hex(p),
                        hex(s.trace)
                    ));
                };
                if let (Some(cs), Some(ce), Some(ps), Some(pe)) =
                    (s.cycle, s.end(), parent.cycle, parent.end())
                {
                    if cs < ps || ce > pe {
                        return Err(format!(
                            "span {} ({}) [{cs}, {ce}] outside parent {} ({}) [{ps}, {pe}]",
                            hex(s.span),
                            s.name,
                            hex(p),
                            parent.name
                        ));
                    }
                }
            }
        }
    }
    // Every trace present must have exactly one root; traces whose spans
    // are all parented never enter `roots`, so walk the full trace set.
    let traces: std::collections::BTreeSet<u64> = spans.iter().map(|s| s.trace).collect();
    for trace in traces {
        let n = roots.get(&trace).copied().unwrap_or(0);
        if n != 1 {
            return Err(format!("trace {} has {n} roots (want exactly 1)", hex(trace)));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_round_trips_and_rejects_garbage() {
        let ctx = TraceContext::root("fleet-demo");
        let wire = ctx.render();
        assert_eq!(wire.len(), 33);
        assert_eq!(TraceContext::parse(&wire), Some(ctx));
        for bad in ["", "abc", "zzzz-zzzz", "0123456789abcdef", "0123456789abcdef-short"] {
            assert_eq!(TraceContext::parse(bad), None, "{bad:?}");
        }
        // Uppercase hex is not the wire form.
        assert_eq!(TraceContext::parse(&wire.to_uppercase()), None);
    }

    #[test]
    fn ids_are_deterministic_and_key_sensitive() {
        let a = TraceContext::root("run-a");
        assert_eq!(a, TraceContext::root("run-a"));
        assert_ne!(a.trace, TraceContext::root("run-b").trace);
        let c1 = a.child("axpy_n1024-c16-multicast", 0);
        let c2 = a.child("axpy_n1024-c16-multicast", 1);
        assert_eq!(c1.trace, a.trace);
        assert_ne!(c1.span, c2.span);
        assert_ne!(child_span(c1.span, "queue"), child_span(c1.span, "execute"));
        assert_eq!(
            self_rooted("deadbeefdeadbeef", "k", 3),
            self_rooted("deadbeefdeadbeef", "k", 3)
        );
    }

    #[test]
    fn span_events_render_deterministically_and_parse_back() {
        let ctx = TraceContext::root("seed-1").child("axpy_n1024-c16-multicast", 4);
        let parent = TraceContext::root("seed-1").span;
        let ev = sim_span("request", ctx, Some(parent), 100, 250)
            .u64("id", 4)
            .str("kernel", "axpy:1024");
        // Event renders through the log's deterministic JSON; round-trip
        // through the log machinery is covered by emitting + parsing.
        let log = crate::obs::log::EventLog::in_memory();
        log.emit(&ev);
        let lines = log.recent();
        assert_eq!(lines.len(), 1);
        let rec = SpanRecord::parse(&lines[0]).expect("span line parses");
        assert_eq!(rec.name, "request");
        assert_eq!((rec.trace, rec.span), (ctx.trace, ctx.span));
        assert_eq!(rec.parent, Some(parent));
        assert_eq!((rec.cycle, rec.dur), (Some(100), 250));
        assert_eq!(rec.end(), Some(350));
        assert_eq!(rec.field_u64("id"), Some(4));
        assert_eq!(rec.field_str("kernel"), Some("axpy:1024"));
        // Non-span lines are skipped.
        assert!(SpanRecord::parse(r#"{"event":"accept","src":"serve"}"#).is_none());
        assert!(SpanRecord::parse("not json").is_none());
    }

    #[test]
    fn tree_checker_accepts_good_trees_and_names_violations() {
        let root = TraceContext::root("t");
        let req = root.child("k", 0);
        let q = TraceContext { trace: req.trace, span: child_span(req.span, "queue") };
        let x = TraceContext { trace: req.trace, span: child_span(req.span, "execute") };
        let log = crate::obs::log::EventLog::in_memory();
        log.emit(&sim_span("root", root, None, 0, 100));
        log.emit(&sim_span("request", req, Some(root.span), 10, 50));
        log.emit(&sim_span("queue", q, Some(req.span), 10, 5));
        log.emit(&sim_span("execute", x, Some(req.span), 15, 45));
        let spans = parse_log(&log.recent().join("\n"));
        assert_eq!(spans.len(), 4);
        check_trees(&spans).unwrap();

        // Orphan parent.
        let mut orphaned = spans.clone();
        orphaned.remove(0);
        let err = check_trees(&orphaned).unwrap_err();
        assert!(err.contains("orphan parent"), "{err}");

        // Child escaping its parent interval.
        let log2 = crate::obs::log::EventLog::in_memory();
        log2.emit(&sim_span("root", root, None, 0, 100));
        log2.emit(&sim_span("request", req, Some(root.span), 90, 50));
        let err = check_trees(&parse_log(&log2.recent().join("\n"))).unwrap_err();
        assert!(err.contains("outside parent"), "{err}");

        // Two roots in one trace.
        let other = TraceContext { trace: root.trace, span: child_span(root.span, "again") };
        let log3 = crate::obs::log::EventLog::in_memory();
        log3.emit(&sim_span("root", root, None, 0, 100));
        log3.emit(&sim_span("root", other, None, 0, 100));
        let err = check_trees(&parse_log(&log3.recent().join("\n"))).unwrap_err();
        assert!(err.contains("2 roots"), "{err}");
    }
}
