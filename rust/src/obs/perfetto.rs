//! Deterministic Chrome trace-event / Perfetto JSON timelines.
//!
//! Renders a [`Trace`] (and optionally an occupancy-engine batch) in the
//! [trace-event format] both `chrome://tracing` and
//! <https://ui.perfetto.dev> load directly:
//!
//! * **pid 1 — host (CVA6):** one lane with the host-side phase spans
//!   (A "Send job information", I "Resume operation on host"; B's host
//!   part is folded into the cluster-side B, matching
//!   [`Trace::host_spans`]).
//! * **pid 2 — clusters:** one lane per cluster, carrying its A–I
//!   [`crate::sim::PhaseSpan`]s.
//! * **pid 3 — coordinator (JCU):** for batches, one lane per JCU slot
//!   with each admitted job's service interval (dispatch → complete),
//!   plus `queue` lanes holding the arrival → dispatch waits
//!   ([`Admission::queue_delay`]), packed greedily so overlapping waits
//!   never share a lane.
//! * **pids 4–6 — recorded spans:** when a span log rides along
//!   (`trace export --spans`), pid 4 carries `request` spans greedily
//!   packed onto lanes, pid 5 their `queue`/`execute` children on the
//!   lane index of their parent request, and pid 6 the client-side
//!   `loadgen`/`client` spans from a `--record` file. Wall-domain spans
//!   (no cycle stamp) have no place on the virtual-cycle axis and are
//!   skipped.
//!
//! Timestamps are **virtual cycles** (1 cycle rendered as 1 µs — the
//! format's native unit; wall time never appears), and every container
//! is either a BTreeMap-ordered object or an explicitly ordered array,
//! so the same request always renders byte-identical JSON — the golden
//! tests and the CI determinism check rely on it.
//!
//! [trace-event format]: https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU

use std::collections::BTreeMap;

use crate::coordinator::{Admission, OccupancyParams};
use crate::obs::span::SpanRecord;
use crate::runtime::json::Json;
use crate::sim::{Phase, Time, Trace};

/// Process ids of the three lane groups.
pub const HOST_PID: u64 = 1;
pub const CLUSTER_PID: u64 = 2;
pub const COORD_PID: u64 = 3;
/// Process ids of the recorded-span lane groups: serve-side `request`
/// spans, their `queue`/`execute` children, and client-side spans.
pub const SPAN_REQUEST_PID: u64 = 4;
pub const SPAN_DETAIL_PID: u64 = 5;
pub const SPAN_CLIENT_PID: u64 = 6;

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        fields
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

fn num(v: u64) -> Json {
    Json::Num(v as f64)
}

fn meta(pid: u64, tid: u64, what: &str, name: &str) -> Json {
    obj(vec![
        ("args", obj(vec![("name", Json::Str(name.to_string()))])),
        ("name", Json::Str(what.to_string())),
        ("ph", Json::Str("M".to_string())),
        ("pid", num(pid)),
        ("tid", num(tid)),
    ])
}

fn span(pid: u64, tid: u64, name: &str, cat: &str, start: Time, end: Time, args: Json) -> Json {
    obj(vec![
        ("args", args),
        ("cat", Json::Str(cat.to_string())),
        ("dur", num(end - start)),
        ("name", Json::Str(name.to_string())),
        ("ph", Json::Str("X".to_string())),
        ("pid", num(pid)),
        ("tid", num(tid)),
        ("ts", num(start)),
    ])
}

fn phase_name(p: Phase) -> String {
    format!("{}: {}", p.letter(), p.name())
}

/// Host + per-cluster lanes of one job's trace, in deterministic order:
/// process/thread metadata first, then host spans, then cluster spans
/// (cluster-major, phases in pipeline order).
fn job_events(trace: &Trace, events: &mut Vec<Json>) {
    events.push(meta(HOST_PID, 0, "process_name", "host (CVA6)"));
    events.push(meta(HOST_PID, 0, "thread_name", "host"));
    events.push(meta(CLUSTER_PID, 0, "process_name", "clusters"));
    for c in 0..trace.n_clusters() {
        events.push(meta(CLUSTER_PID, c as u64, "thread_name", &format!("cluster {c}")));
    }
    for p in Phase::ALL {
        if let Some(s) = trace.host_spans.get(&p) {
            events.push(span(
                HOST_PID,
                0,
                &phase_name(p),
                "host",
                s.start,
                s.end,
                obj(vec![("phase", Json::Str(p.letter().to_string()))]),
            ));
        }
    }
    for (c, spans) in trace.cluster_spans.iter().enumerate() {
        for p in Phase::ALL {
            if let Some(s) = spans.get(&p) {
                events.push(span(
                    CLUSTER_PID,
                    c as u64,
                    &phase_name(p),
                    "phase",
                    s.start,
                    s.end,
                    obj(vec![("phase", Json::Str(p.letter().to_string()))]),
                ));
            }
        }
    }
}

/// Coordinator lanes of an occupancy batch: JCU-slot lanes carry each
/// job's dispatch → complete service interval, `queue` lanes its
/// arrival → dispatch wait. A slot lane never overlaps by construction
/// (a slot holds one job at a time); queue waits are packed greedily
/// onto the first lane whose previous wait has ended, so overlapping
/// waits land on distinct lanes.
fn batch_events(params: &OccupancyParams, admissions: &[Admission], events: &mut Vec<Json>) {
    events.push(meta(COORD_PID, 0, "process_name", "coordinator (JCU)"));
    for s in 0..params.jcu_slots as u64 {
        events.push(meta(COORD_PID, s, "thread_name", &format!("JCU slot {s}")));
    }
    // Greedy interval packing of the nonzero queue waits.
    let mut queue_lane_ends: Vec<Time> = Vec::new();
    let mut queue_spans: Vec<(usize, &Admission)> = Vec::new();
    for a in admissions.iter().filter(|a| a.queue_delay > 0) {
        let lane = match queue_lane_ends.iter().position(|&end| end <= a.arrival) {
            Some(lane) => lane,
            None => {
                queue_lane_ends.push(0);
                queue_lane_ends.len() - 1
            }
        };
        queue_lane_ends[lane] = a.start;
        queue_spans.push((lane, a));
    }
    let queue_tid = |lane: usize| params.jcu_slots as u64 + lane as u64;
    for lane in 0..queue_lane_ends.len() {
        events.push(meta(COORD_PID, queue_tid(lane), "thread_name", &format!("queue {lane}")));
    }
    for a in admissions {
        events.push(span(
            COORD_PID,
            u64::from(a.slot),
            &format!("job {}", a.seq),
            "service",
            a.start,
            a.completion,
            obj(vec![
                ("arrival", num(a.arrival)),
                ("queue_delay", num(a.queue_delay)),
                ("seq", num(a.seq)),
            ]),
        ));
    }
    for (lane, a) in queue_spans {
        events.push(span(
            COORD_PID,
            queue_tid(lane),
            &format!("job {} queued", a.seq),
            "queue",
            a.arrival,
            a.start,
            obj(vec![("seq", num(a.seq))]),
        ));
    }
}

fn hex16(v: u64) -> String {
    format!("{v:016x}")
}

fn span_args(s: &SpanRecord) -> Json {
    let mut fields = vec![
        ("span", Json::Str(hex16(s.span))),
        ("trace", Json::Str(hex16(s.trace))),
    ];
    if let Some(id) = s.field_u64("id") {
        fields.push(("id", num(id)));
    }
    if let Some(k) = s.field_str("kernel") {
        fields.push(("kernel", Json::Str(k.to_string())));
    }
    obj(fields)
}

fn span_label(s: &SpanRecord) -> String {
    match s.field_u64("id") {
        Some(id) => format!("{} {id}", s.name),
        None => s.name.clone(),
    }
}

/// Recorded-span lanes. `request` spans are packed greedily (sorted by
/// start, admission seq, span id; first lane whose previous span has
/// ended) so concurrent requests never share a lane. `queue`/`execute`
/// children reuse their parent request's lane index on the detail pid —
/// they tile arrival → dispatch → complete inside the parent, so a
/// detail lane can never overlap either. Client-side spans get their
/// own greedy packing; wall-domain spans (no cycle) are skipped.
fn span_events(spans: &[SpanRecord], events: &mut Vec<Json>) {
    let mut requests: Vec<&SpanRecord> = spans
        .iter()
        .filter(|s| s.name == "request" && s.cycle.is_some())
        .collect();
    requests.sort_by_key(|s| (s.cycle, s.field_u64("seq"), s.span));
    let mut lane_ends: Vec<Time> = Vec::new();
    let mut lane_of: BTreeMap<u64, usize> = BTreeMap::new();
    for r in &requests {
        let start = r.cycle.unwrap();
        let lane = match lane_ends.iter().position(|&end| end <= start) {
            Some(lane) => lane,
            None => {
                lane_ends.push(0);
                lane_ends.len() - 1
            }
        };
        lane_ends[lane] = start + r.dur;
        lane_of.insert(r.span, lane);
    }
    let mut children: Vec<&SpanRecord> = spans
        .iter()
        .filter(|s| {
            (s.name == "queue" || s.name == "execute")
                && s.cycle.is_some()
                && s.parent.is_some_and(|p| lane_of.contains_key(&p))
        })
        .collect();
    children.sort_by_key(|s| (s.cycle, s.span));
    let mut clients: Vec<&SpanRecord> = spans
        .iter()
        .filter(|s| (s.name == "client" || s.name == "loadgen") && s.cycle.is_some())
        .collect();
    clients.sort_by_key(|s| (s.cycle, s.span));
    let mut client_lane_ends: Vec<Time> = Vec::new();
    let mut client_lanes: Vec<usize> = Vec::new();
    for c in &clients {
        let start = c.cycle.unwrap();
        let lane = match client_lane_ends.iter().position(|&end| end <= start) {
            Some(lane) => lane,
            None => {
                client_lane_ends.push(0);
                client_lane_ends.len() - 1
            }
        };
        client_lane_ends[lane] = start + c.dur;
        client_lanes.push(lane);
    }
    if !requests.is_empty() {
        events.push(meta(SPAN_REQUEST_PID, 0, "process_name", "requests (recorded spans)"));
        for lane in 0..lane_ends.len() {
            events.push(meta(
                SPAN_REQUEST_PID,
                lane as u64,
                "thread_name",
                &format!("request lane {lane}"),
            ));
        }
    }
    if !children.is_empty() {
        events.push(meta(SPAN_DETAIL_PID, 0, "process_name", "queue/execute (recorded spans)"));
        for lane in 0..lane_ends.len() {
            events.push(meta(
                SPAN_DETAIL_PID,
                lane as u64,
                "thread_name",
                &format!("detail lane {lane}"),
            ));
        }
    }
    if !clients.is_empty() {
        events.push(meta(SPAN_CLIENT_PID, 0, "process_name", "clients (recorded spans)"));
        for lane in 0..client_lane_ends.len() {
            events.push(meta(
                SPAN_CLIENT_PID,
                lane as u64,
                "thread_name",
                &format!("client lane {lane}"),
            ));
        }
    }
    for r in &requests {
        let start = r.cycle.unwrap();
        events.push(span(
            SPAN_REQUEST_PID,
            lane_of[&r.span] as u64,
            &span_label(r),
            "request",
            start,
            start + r.dur,
            span_args(r),
        ));
    }
    for c in &children {
        let start = c.cycle.unwrap();
        events.push(span(
            SPAN_DETAIL_PID,
            lane_of[&c.parent.unwrap()] as u64,
            &span_label(c),
            "detail",
            start,
            start + c.dur,
            span_args(c),
        ));
    }
    for (c, lane) in clients.iter().zip(client_lanes) {
        let start = c.cycle.unwrap();
        events.push(span(
            SPAN_CLIENT_PID,
            lane as u64,
            &span_label(c),
            "client",
            start,
            start + c.dur,
            span_args(c),
        ));
    }
}

fn document(label: &str, events: Vec<Json>) -> Json {
    obj(vec![
        ("displayTimeUnit", Json::Str("ms".to_string())),
        (
            "otherData",
            obj(vec![
                ("clock", Json::Str("virtual cycles (1 cycle = 1us)".to_string())),
                ("label", Json::Str(label.to_string())),
            ]),
        ),
        ("traceEvents", Json::Arr(events)),
    ])
}

/// One isolated job as a timeline document (host + cluster lanes).
pub fn job_timeline(label: &str, trace: &Trace) -> Json {
    let mut events = Vec::new();
    job_events(trace, &mut events);
    document(label, events)
}

/// An occupancy batch: the isolated job's host/cluster lanes (the phase
/// anatomy every admission repeats) plus the coordinator's JCU-slot and
/// queue lanes on the batch's shared virtual timeline.
pub fn batch_timeline(
    label: &str,
    trace: &Trace,
    params: &OccupancyParams,
    admissions: &[Admission],
) -> Json {
    let mut events = Vec::new();
    job_events(trace, &mut events);
    batch_events(params, admissions, &mut events);
    document(label, events)
}

/// Recorded spans alone as a timeline document (pids 4–6).
pub fn spans_timeline(label: &str, spans: &[SpanRecord]) -> Json {
    let mut events = Vec::new();
    span_events(spans, &mut events);
    document(label, events)
}

/// A job timeline with recorded span lanes merged in: one request's
/// journey (client → request → queue/execute) rendered next to the
/// phase anatomy it executes, on the shared virtual-cycle axis.
pub fn job_timeline_with_spans(label: &str, trace: &Trace, spans: &[SpanRecord]) -> Json {
    let mut events = Vec::new();
    job_events(trace, &mut events);
    span_events(spans, &mut events);
    document(label, events)
}

/// A batch timeline with recorded span lanes merged in (pids 4–6
/// alongside the host/cluster/coordinator lanes).
pub fn batch_timeline_with_spans(
    label: &str,
    trace: &Trace,
    params: &OccupancyParams,
    admissions: &[Admission],
    spans: &[SpanRecord],
) -> Json {
    let mut events = Vec::new();
    job_events(trace, &mut events);
    batch_events(params, admissions, &mut events);
    span_events(spans, &mut events);
    document(label, events)
}

/// Serialize a timeline document (one line, trailing newline).
pub fn render(doc: &Json) -> String {
    format!("{doc}\n")
}

/// Number of duration (`ph: "X"`) events in a document — the CLI's
/// summary line and the CI span-count check.
pub fn span_count(doc: &Json) -> usize {
    doc.get("traceEvents")
        .and_then(Json::as_arr)
        .map(|events| {
            events
                .iter()
                .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
                .count()
        })
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::OccupancyModel;
    use crate::kernels::JobSpec;
    use crate::offload::RoutineKind;
    use crate::sweep::OffloadRequest;

    /// Collect (pid, tid) → sorted [ts, ts+dur) intervals.
    fn lanes(doc: &Json) -> BTreeMap<(u64, u64), Vec<(u64, u64)>> {
        let mut lanes: BTreeMap<(u64, u64), Vec<(u64, u64)>> = BTreeMap::new();
        for e in doc.get("traceEvents").unwrap().as_arr().unwrap() {
            if e.get("ph").and_then(Json::as_str) != Some("X") {
                continue;
            }
            let pid = e.get("pid").unwrap().as_u64().unwrap();
            let tid = e.get("tid").unwrap().as_u64().unwrap();
            let ts = e.get("ts").unwrap().as_u64().unwrap();
            let dur = e.get("dur").unwrap().as_u64().unwrap();
            lanes.entry((pid, tid)).or_default().push((ts, ts + dur));
        }
        for spans in lanes.values_mut() {
            spans.sort_unstable();
        }
        lanes
    }

    fn assert_lanes_non_overlapping(doc: &Json) {
        for ((pid, tid), spans) in lanes(doc) {
            for w in spans.windows(2) {
                assert!(
                    w[0].1 <= w[1].0,
                    "lane ({pid},{tid}) overlaps: {:?} vs {:?}",
                    w[0],
                    w[1]
                );
            }
        }
    }

    fn small_trace() -> Trace {
        OffloadRequest::new(JobSpec::Axpy { n: 256 }, 2, RoutineKind::Multicast)
            .run(&Config::default())
    }

    #[test]
    fn job_timeline_is_byte_deterministic_and_parses() {
        let trace = small_trace();
        let a = render(&job_timeline("axpy:256 c2 multicast", &trace));
        let b = render(&job_timeline("axpy:256 c2 multicast", &trace));
        assert_eq!(a, b, "same trace, same bytes");
        let doc = Json::parse(&a).unwrap();
        assert_eq!(doc.get("displayTimeUnit").unwrap().as_str(), Some("ms"));
        assert!(span_count(&doc) >= 2 + 2, "host A/I plus per-cluster phases");
    }

    #[test]
    fn job_spans_stay_on_their_lanes_without_overlap_and_tile_the_total() {
        let trace = small_trace();
        let doc = job_timeline("axpy:256 c2 multicast", &trace);
        assert_lanes_non_overlapping(&doc);
        let lanes = lanes(&doc);
        // One host lane + one lane per cluster.
        assert!(lanes.contains_key(&(HOST_PID, 0)));
        assert!(lanes.contains_key(&(CLUSTER_PID, 0)));
        assert!(lanes.contains_key(&(CLUSTER_PID, 1)));
        // Spans live on [0, total] and the last one ends exactly at the
        // job's end-to-end total (the host resume for offloaded runs).
        let max_end = lanes.values().flatten().map(|&(_, e)| e).max().unwrap();
        assert_eq!(max_end, trace.total);
        let min_start = lanes.values().flatten().map(|&(s, _)| s).min().unwrap();
        assert_eq!(min_start, 0, "phase A starts the timeline");
        // The span count is exactly what the trace recorded.
        let recorded: usize = trace.host_spans.len()
            + trace.cluster_spans.iter().map(|m| m.len()).sum::<usize>();
        assert_eq!(span_count(&doc), recorded);
    }

    #[test]
    fn batch_timeline_carries_slot_and_queue_lanes() {
        let trace = small_trace();
        let service = trace.total;
        let params = OccupancyParams {
            capacity: 4,
            jcu_slots: 2,
            inflight: 4,
            arrival_gap: 0,
        };
        let mut model = OccupancyModel::new(params);
        // Four back-to-back jobs of 2 clusters on a 4-cluster fabric with
        // 2 slots: jobs 2 and 3 must queue.
        let admissions: Vec<Admission> =
            (0..4).map(|_| model.admit_at(0, 2, service)).collect();
        model.finish();
        assert!(admissions.iter().any(|a| a.queue_delay > 0), "batch must contend");
        let doc = batch_timeline("batch", &trace, &params, &admissions);
        assert_lanes_non_overlapping(&doc);
        let lanes = lanes(&doc);
        // Slot lanes 0 and 1 under the coordinator pid, plus >= 1 queue lane.
        assert!(lanes.contains_key(&(COORD_PID, 0)));
        assert!(lanes.contains_key(&(COORD_PID, 1)));
        assert!(lanes.contains_key(&(COORD_PID, 2)), "queue lane expected");
        // Every admission's service interval is a span of exactly
        // `service` cycles on its slot lane.
        for a in &admissions {
            let slot_spans = &lanes[&(COORD_PID, u64::from(a.slot))];
            assert!(
                slot_spans.contains(&(a.start, a.start + service)),
                "admission {a:?} missing from slot lane"
            );
        }
        // Deterministic bytes for batches too.
        assert_eq!(
            render(&batch_timeline("batch", &trace, &params, &admissions)),
            render(&doc)
        );
    }

    fn rec(ev: crate::obs::log::Event) -> SpanRecord {
        SpanRecord::parse(&ev.render()).unwrap()
    }

    #[test]
    fn recorded_span_lanes_pack_and_stay_child_aligned() {
        use crate::obs::span::{child_span, sim_span, TraceContext};
        let root = TraceContext::root("perfetto-test");
        let r1 = root.child("a", 0);
        let r2 = root.child("a", 1);
        let q1 = TraceContext { trace: r1.trace, span: child_span(r1.span, "queue") };
        let x1 = TraceContext { trace: r1.trace, span: child_span(r1.span, "execute") };
        let spans = vec![
            rec(sim_span("request", r1, None, 0, 100).u64("id", 1).u64("seq", 0)),
            rec(sim_span("queue", q1, Some(r1.span), 0, 20).u64("id", 1)),
            rec(sim_span("execute", x1, Some(r1.span), 20, 80).u64("id", 1)),
            rec(sim_span("request", r2, None, 10, 100).u64("id", 2).u64("seq", 1)),
            rec(sim_span("client", root.child("c", 0), Some(root.span), 0, 100).u64("id", 1)),
        ];
        let doc = spans_timeline("spans", &spans);
        assert_lanes_non_overlapping(&doc);
        let lanes = lanes(&doc);
        assert!(lanes.contains_key(&(SPAN_REQUEST_PID, 0)));
        assert!(
            lanes.contains_key(&(SPAN_REQUEST_PID, 1)),
            "overlapping requests must split onto two lanes"
        );
        // The queue/execute children tile their parent request's
        // interval on the matching detail lane.
        assert_eq!(lanes[&(SPAN_DETAIL_PID, 0)], vec![(0, 20), (20, 100)]);
        assert!(lanes.contains_key(&(SPAN_CLIENT_PID, 0)));
        assert_eq!(span_count(&doc), 5);
        // Deterministic bytes, merged or standalone.
        assert_eq!(render(&spans_timeline("spans", &spans)), render(&doc));
        let merged = job_timeline_with_spans("merged", &small_trace(), &spans);
        assert_lanes_non_overlapping(&merged);
        assert_eq!(
            render(&job_timeline_with_spans("merged", &small_trace(), &spans)),
            render(&merged)
        );
    }

    #[test]
    fn wall_spans_are_left_off_the_cycle_axis() {
        use crate::obs::span::{wall_span, TraceContext};
        let root = TraceContext::root("wall");
        let spans = vec![rec(wall_span("fleet_run", root, None))];
        assert_eq!(span_count(&spans_timeline("spans", &spans)), 0);
    }

    #[test]
    fn overlapping_queue_waits_get_distinct_lanes() {
        let params = OccupancyParams {
            capacity: 32,
            jcu_slots: 1,
            inflight: 8,
            arrival_gap: 0,
        };
        let mut model = OccupancyModel::new(params);
        // One slot, three simultaneous arrivals: jobs 1 and 2 wait
        // overlapping intervals and must not share a queue lane.
        let admissions: Vec<Admission> =
            (0..3).map(|_| model.admit_at(0, 32, 100)).collect();
        model.finish();
        let trace = Trace::new(0);
        let doc = batch_timeline("queued", &trace, &params, &admissions);
        assert_lanes_non_overlapping(&doc);
        let lanes = lanes(&doc);
        assert!(lanes.contains_key(&(COORD_PID, 1)), "first queue lane");
        assert!(lanes.contains_key(&(COORD_PID, 2)), "second queue lane");
    }
}
