//! Deterministic Chrome trace-event / Perfetto JSON timelines.
//!
//! Renders a [`Trace`] (and optionally an occupancy-engine batch) in the
//! [trace-event format] both `chrome://tracing` and
//! <https://ui.perfetto.dev> load directly:
//!
//! * **pid 1 — host (CVA6):** one lane with the host-side phase spans
//!   (A "Send job information", I "Resume operation on host"; B's host
//!   part is folded into the cluster-side B, matching
//!   [`Trace::host_spans`]).
//! * **pid 2 — clusters:** one lane per cluster, carrying its A–I
//!   [`crate::sim::PhaseSpan`]s.
//! * **pid 3 — coordinator (JCU):** for batches, one lane per JCU slot
//!   with each admitted job's service interval (dispatch → complete),
//!   plus `queue` lanes holding the arrival → dispatch waits
//!   ([`Admission::queue_delay`]), packed greedily so overlapping waits
//!   never share a lane.
//!
//! Timestamps are **virtual cycles** (1 cycle rendered as 1 µs — the
//! format's native unit; wall time never appears), and every container
//! is either a BTreeMap-ordered object or an explicitly ordered array,
//! so the same request always renders byte-identical JSON — the golden
//! tests and the CI determinism check rely on it.
//!
//! [trace-event format]: https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU

use std::collections::BTreeMap;

use crate::coordinator::{Admission, OccupancyParams};
use crate::runtime::json::Json;
use crate::sim::{Phase, Time, Trace};

/// Process ids of the three lane groups.
pub const HOST_PID: u64 = 1;
pub const CLUSTER_PID: u64 = 2;
pub const COORD_PID: u64 = 3;

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        fields
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

fn num(v: u64) -> Json {
    Json::Num(v as f64)
}

fn meta(pid: u64, tid: u64, what: &str, name: &str) -> Json {
    obj(vec![
        ("args", obj(vec![("name", Json::Str(name.to_string()))])),
        ("name", Json::Str(what.to_string())),
        ("ph", Json::Str("M".to_string())),
        ("pid", num(pid)),
        ("tid", num(tid)),
    ])
}

fn span(pid: u64, tid: u64, name: &str, cat: &str, start: Time, end: Time, args: Json) -> Json {
    obj(vec![
        ("args", args),
        ("cat", Json::Str(cat.to_string())),
        ("dur", num(end - start)),
        ("name", Json::Str(name.to_string())),
        ("ph", Json::Str("X".to_string())),
        ("pid", num(pid)),
        ("tid", num(tid)),
        ("ts", num(start)),
    ])
}

fn phase_name(p: Phase) -> String {
    format!("{}: {}", p.letter(), p.name())
}

/// Host + per-cluster lanes of one job's trace, in deterministic order:
/// process/thread metadata first, then host spans, then cluster spans
/// (cluster-major, phases in pipeline order).
fn job_events(trace: &Trace, events: &mut Vec<Json>) {
    events.push(meta(HOST_PID, 0, "process_name", "host (CVA6)"));
    events.push(meta(HOST_PID, 0, "thread_name", "host"));
    events.push(meta(CLUSTER_PID, 0, "process_name", "clusters"));
    for c in 0..trace.n_clusters() {
        events.push(meta(CLUSTER_PID, c as u64, "thread_name", &format!("cluster {c}")));
    }
    for p in Phase::ALL {
        if let Some(s) = trace.host_spans.get(&p) {
            events.push(span(
                HOST_PID,
                0,
                &phase_name(p),
                "host",
                s.start,
                s.end,
                obj(vec![("phase", Json::Str(p.letter().to_string()))]),
            ));
        }
    }
    for (c, spans) in trace.cluster_spans.iter().enumerate() {
        for p in Phase::ALL {
            if let Some(s) = spans.get(&p) {
                events.push(span(
                    CLUSTER_PID,
                    c as u64,
                    &phase_name(p),
                    "phase",
                    s.start,
                    s.end,
                    obj(vec![("phase", Json::Str(p.letter().to_string()))]),
                ));
            }
        }
    }
}

/// Coordinator lanes of an occupancy batch: JCU-slot lanes carry each
/// job's dispatch → complete service interval, `queue` lanes its
/// arrival → dispatch wait. A slot lane never overlaps by construction
/// (a slot holds one job at a time); queue waits are packed greedily
/// onto the first lane whose previous wait has ended, so overlapping
/// waits land on distinct lanes.
fn batch_events(params: &OccupancyParams, admissions: &[Admission], events: &mut Vec<Json>) {
    events.push(meta(COORD_PID, 0, "process_name", "coordinator (JCU)"));
    for s in 0..params.jcu_slots as u64 {
        events.push(meta(COORD_PID, s, "thread_name", &format!("JCU slot {s}")));
    }
    // Greedy interval packing of the nonzero queue waits.
    let mut queue_lane_ends: Vec<Time> = Vec::new();
    let mut queue_spans: Vec<(usize, &Admission)> = Vec::new();
    for a in admissions.iter().filter(|a| a.queue_delay > 0) {
        let lane = match queue_lane_ends.iter().position(|&end| end <= a.arrival) {
            Some(lane) => lane,
            None => {
                queue_lane_ends.push(0);
                queue_lane_ends.len() - 1
            }
        };
        queue_lane_ends[lane] = a.start;
        queue_spans.push((lane, a));
    }
    let queue_tid = |lane: usize| params.jcu_slots as u64 + lane as u64;
    for lane in 0..queue_lane_ends.len() {
        events.push(meta(COORD_PID, queue_tid(lane), "thread_name", &format!("queue {lane}")));
    }
    for a in admissions {
        events.push(span(
            COORD_PID,
            u64::from(a.slot),
            &format!("job {}", a.seq),
            "service",
            a.start,
            a.completion,
            obj(vec![
                ("arrival", num(a.arrival)),
                ("queue_delay", num(a.queue_delay)),
                ("seq", num(a.seq)),
            ]),
        ));
    }
    for (lane, a) in queue_spans {
        events.push(span(
            COORD_PID,
            queue_tid(lane),
            &format!("job {} queued", a.seq),
            "queue",
            a.arrival,
            a.start,
            obj(vec![("seq", num(a.seq))]),
        ));
    }
}

fn document(label: &str, events: Vec<Json>) -> Json {
    obj(vec![
        ("displayTimeUnit", Json::Str("ms".to_string())),
        (
            "otherData",
            obj(vec![
                ("clock", Json::Str("virtual cycles (1 cycle = 1us)".to_string())),
                ("label", Json::Str(label.to_string())),
            ]),
        ),
        ("traceEvents", Json::Arr(events)),
    ])
}

/// One isolated job as a timeline document (host + cluster lanes).
pub fn job_timeline(label: &str, trace: &Trace) -> Json {
    let mut events = Vec::new();
    job_events(trace, &mut events);
    document(label, events)
}

/// An occupancy batch: the isolated job's host/cluster lanes (the phase
/// anatomy every admission repeats) plus the coordinator's JCU-slot and
/// queue lanes on the batch's shared virtual timeline.
pub fn batch_timeline(
    label: &str,
    trace: &Trace,
    params: &OccupancyParams,
    admissions: &[Admission],
) -> Json {
    let mut events = Vec::new();
    job_events(trace, &mut events);
    batch_events(params, admissions, &mut events);
    document(label, events)
}

/// Serialize a timeline document (one line, trailing newline).
pub fn render(doc: &Json) -> String {
    format!("{doc}\n")
}

/// Number of duration (`ph: "X"`) events in a document — the CLI's
/// summary line and the CI span-count check.
pub fn span_count(doc: &Json) -> usize {
    doc.get("traceEvents")
        .and_then(Json::as_arr)
        .map(|events| {
            events
                .iter()
                .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
                .count()
        })
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::OccupancyModel;
    use crate::kernels::JobSpec;
    use crate::offload::RoutineKind;
    use crate::sweep::OffloadRequest;

    /// Collect (pid, tid) → sorted [ts, ts+dur) intervals.
    fn lanes(doc: &Json) -> BTreeMap<(u64, u64), Vec<(u64, u64)>> {
        let mut lanes: BTreeMap<(u64, u64), Vec<(u64, u64)>> = BTreeMap::new();
        for e in doc.get("traceEvents").unwrap().as_arr().unwrap() {
            if e.get("ph").and_then(Json::as_str) != Some("X") {
                continue;
            }
            let pid = e.get("pid").unwrap().as_u64().unwrap();
            let tid = e.get("tid").unwrap().as_u64().unwrap();
            let ts = e.get("ts").unwrap().as_u64().unwrap();
            let dur = e.get("dur").unwrap().as_u64().unwrap();
            lanes.entry((pid, tid)).or_default().push((ts, ts + dur));
        }
        for spans in lanes.values_mut() {
            spans.sort_unstable();
        }
        lanes
    }

    fn assert_lanes_non_overlapping(doc: &Json) {
        for ((pid, tid), spans) in lanes(doc) {
            for w in spans.windows(2) {
                assert!(
                    w[0].1 <= w[1].0,
                    "lane ({pid},{tid}) overlaps: {:?} vs {:?}",
                    w[0],
                    w[1]
                );
            }
        }
    }

    fn small_trace() -> Trace {
        OffloadRequest::new(JobSpec::Axpy { n: 256 }, 2, RoutineKind::Multicast)
            .run(&Config::default())
    }

    #[test]
    fn job_timeline_is_byte_deterministic_and_parses() {
        let trace = small_trace();
        let a = render(&job_timeline("axpy:256 c2 multicast", &trace));
        let b = render(&job_timeline("axpy:256 c2 multicast", &trace));
        assert_eq!(a, b, "same trace, same bytes");
        let doc = Json::parse(&a).unwrap();
        assert_eq!(doc.get("displayTimeUnit").unwrap().as_str(), Some("ms"));
        assert!(span_count(&doc) >= 2 + 2, "host A/I plus per-cluster phases");
    }

    #[test]
    fn job_spans_stay_on_their_lanes_without_overlap_and_tile_the_total() {
        let trace = small_trace();
        let doc = job_timeline("axpy:256 c2 multicast", &trace);
        assert_lanes_non_overlapping(&doc);
        let lanes = lanes(&doc);
        // One host lane + one lane per cluster.
        assert!(lanes.contains_key(&(HOST_PID, 0)));
        assert!(lanes.contains_key(&(CLUSTER_PID, 0)));
        assert!(lanes.contains_key(&(CLUSTER_PID, 1)));
        // Spans live on [0, total] and the last one ends exactly at the
        // job's end-to-end total (the host resume for offloaded runs).
        let max_end = lanes.values().flatten().map(|&(_, e)| e).max().unwrap();
        assert_eq!(max_end, trace.total);
        let min_start = lanes.values().flatten().map(|&(s, _)| s).min().unwrap();
        assert_eq!(min_start, 0, "phase A starts the timeline");
        // The span count is exactly what the trace recorded.
        let recorded: usize = trace.host_spans.len()
            + trace.cluster_spans.iter().map(|m| m.len()).sum::<usize>();
        assert_eq!(span_count(&doc), recorded);
    }

    #[test]
    fn batch_timeline_carries_slot_and_queue_lanes() {
        let trace = small_trace();
        let service = trace.total;
        let params = OccupancyParams {
            capacity: 4,
            jcu_slots: 2,
            inflight: 4,
            arrival_gap: 0,
        };
        let mut model = OccupancyModel::new(params);
        // Four back-to-back jobs of 2 clusters on a 4-cluster fabric with
        // 2 slots: jobs 2 and 3 must queue.
        let admissions: Vec<Admission> =
            (0..4).map(|_| model.admit_at(0, 2, service)).collect();
        model.finish();
        assert!(admissions.iter().any(|a| a.queue_delay > 0), "batch must contend");
        let doc = batch_timeline("batch", &trace, &params, &admissions);
        assert_lanes_non_overlapping(&doc);
        let lanes = lanes(&doc);
        // Slot lanes 0 and 1 under the coordinator pid, plus >= 1 queue lane.
        assert!(lanes.contains_key(&(COORD_PID, 0)));
        assert!(lanes.contains_key(&(COORD_PID, 1)));
        assert!(lanes.contains_key(&(COORD_PID, 2)), "queue lane expected");
        // Every admission's service interval is a span of exactly
        // `service` cycles on its slot lane.
        for a in &admissions {
            let slot_spans = &lanes[&(COORD_PID, u64::from(a.slot))];
            assert!(
                slot_spans.contains(&(a.start, a.start + service)),
                "admission {a:?} missing from slot lane"
            );
        }
        // Deterministic bytes for batches too.
        assert_eq!(
            render(&batch_timeline("batch", &trace, &params, &admissions)),
            render(&doc)
        );
    }

    #[test]
    fn overlapping_queue_waits_get_distinct_lanes() {
        let params = OccupancyParams {
            capacity: 32,
            jcu_slots: 1,
            inflight: 8,
            arrival_gap: 0,
        };
        let mut model = OccupancyModel::new(params);
        // One slot, three simultaneous arrivals: jobs 1 and 2 wait
        // overlapping intervals and must not share a queue lane.
        let admissions: Vec<Admission> =
            (0..3).map(|_| model.admit_at(0, 32, 100)).collect();
        model.finish();
        let trace = Trace::new(0);
        let doc = batch_timeline("queued", &trace, &params, &admissions);
        assert_lanes_non_overlapping(&doc);
        let lanes = lanes(&doc);
        assert!(lanes.contains_key(&(COORD_PID, 1)), "first queue lane");
        assert!(lanes.contains_key(&(COORD_PID, 2)), "second queue lane");
    }
}
