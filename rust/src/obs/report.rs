//! Overhead attribution over a campaign trace store.
//!
//! A store (`<root>/<fingerprint>/<request-key>.json`) already holds
//! full phase-resolved traces for everything a campaign, fleet, or
//! serve daemon ever simulated. This module turns that recorded
//! traffic back into the paper's analysis without re-simulating
//! anything:
//!
//! * [`scan`] walks a store root and decodes every trace, recovering
//!   each request's spec/clusters/routine from its on-disk key
//!   ([`crate::campaign::store::request_key`] spelled backwards).
//! * [`decompose`] is the headline split of §5: per (kernel, size,
//!   clusters, routine), end-to-end cycles vs. the critical-path
//!   execute phase — everything else is *offload overhead* (Fig. 2).
//! * [`phase_bands`] re-derives Fig. 11's per-phase min/avg/max bands
//!   through the exact `exp/fig11` math
//!   ([`crate::exp::fig11::bands_of`]), so `occamy trace report` over a
//!   store that holds the paper grid reproduces the figure
//!   bit-identically.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use crate::campaign::codec;
use crate::exp::fig11::{self, Band};
use crate::offload::RoutineKind;
use crate::sim::{Phase, Trace};

/// One decoded trace with the request recovered from its store key.
#[derive(Debug, Clone)]
pub struct StoredTrace {
    /// Config fingerprint directory the trace came from.
    pub fingerprint: String,
    /// Spec part of the request key, e.g. `axpy_n1024`.
    pub spec_key: String,
    pub n_clusters: usize,
    pub routine: RoutineKind,
    pub trace: Arc<Trace>,
}

/// Invert [`crate::campaign::store::request_key`]: split
/// `<spec>-c<clusters>-<routine>` back into its parts. Spec ids are
/// `[a-z0-9_]` only, so the first `-c` is always the separator; the
/// routine half is taken whole because routine names may themselves
/// contain `-` (`mcast-only`, `jcu-only` — splitting at the *last* `-`
/// used to drop every ablation trace from `trace report`). `None` for
/// anything that is not a store key (foreign files are skipped, not
/// errors).
pub fn parse_request_key(stem: &str) -> Option<(String, usize, RoutineKind)> {
    let (spec_key, rest) = stem.split_once("-c")?;
    let (clusters, routine) = rest.split_once('-')?;
    let routine = RoutineKind::parse(routine)?;
    let n_clusters: usize = clusters.parse().ok()?;
    if spec_key.is_empty() || n_clusters == 0 {
        return None;
    }
    Some((spec_key.to_string(), n_clusters, routine))
}

fn is_fingerprint(name: &str) -> bool {
    name.len() == 16 && name.chars().all(|c| c.is_ascii_hexdigit() && !c.is_ascii_uppercase())
}

fn sorted_names(dir: &Path, keep: impl Fn(&str) -> bool) -> anyhow::Result<Vec<String>> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("read store dir {}: {e}", dir.display()))?;
    let mut names: Vec<String> = entries
        .filter_map(Result::ok)
        .filter_map(|e| e.file_name().into_string().ok())
        .filter(|n| keep(n))
        .collect();
    names.sort();
    Ok(names)
}

/// Decode every trace under a store root, in deterministic
/// (fingerprint, request-key) order. Corrupt traces are skipped with a
/// warning, matching the store's own corruption tolerance; files that
/// are not store keys are ignored silently.
pub fn scan(root: &Path) -> anyhow::Result<Vec<StoredTrace>> {
    anyhow::ensure!(
        root.is_dir(),
        "trace store {} does not exist (run a campaign/serve with --store first)",
        root.display()
    );
    let mut out = Vec::new();
    for fp in sorted_names(root, is_fingerprint)? {
        let dir = root.join(&fp);
        let stems = sorted_names(&dir, |n| n.ends_with(".json") && !n.starts_with('.'))?;
        for file in stems {
            let stem = file.trim_end_matches(".json");
            let Some((spec_key, n_clusters, routine)) = parse_request_key(stem) else {
                continue;
            };
            let path = dir.join(&file);
            let Ok(text) = std::fs::read_to_string(&path) else {
                continue;
            };
            match codec::trace_from_str(&text) {
                Ok(trace) => out.push(StoredTrace {
                    fingerprint: fp.clone(),
                    spec_key,
                    n_clusters,
                    routine,
                    trace,
                }),
                Err(e) => eprintln!("trace report: skipping corrupt {} ({e})", path.display()),
            }
        }
    }
    Ok(out)
}

/// The §5 overhead split of one (kernel/size, clusters, routine) group:
/// end-to-end cycles vs. the critical-path execute phase, aggregated
/// over every matching trace in the store (min/avg/max across traces —
/// one trace per config fingerprint in the common case).
#[derive(Debug, Clone)]
pub struct Decomposition {
    pub spec_key: String,
    pub n_clusters: usize,
    pub routine: RoutineKind,
    /// Traces aggregated into this row.
    pub traces: usize,
    pub total_avg: f64,
    /// Mean critical-path execute cycles (the slowest cluster's F phase
    /// — the paper's "useful work" reference).
    pub execute_avg: f64,
    pub overhead_min: u64,
    pub overhead_avg: f64,
    pub overhead_max: u64,
}

impl Decomposition {
    /// Offload overhead as a percentage of the end-to-end runtime.
    pub fn overhead_pct(&self) -> f64 {
        if self.total_avg > 0.0 {
            100.0 * self.overhead_avg / self.total_avg
        } else {
            0.0
        }
    }
}

/// Per-trace overhead: end-to-end total minus the slowest cluster's
/// execute phase. Ideal runs with no recorded execute phase (there are
/// none — every routine executes) degrade to the full total.
fn overhead_of(trace: &Trace) -> u64 {
    let execute = trace.stats(Phase::Execute).map(|s| s.max).unwrap_or(0);
    trace.total.saturating_sub(execute)
}

/// Group scanned traces into the overhead decomposition, sorted by
/// (spec key, clusters, routine name).
pub fn decompose(entries: &[StoredTrace]) -> Vec<Decomposition> {
    let mut groups: BTreeMap<(String, usize, &'static str), Vec<&StoredTrace>> = BTreeMap::new();
    for e in entries {
        groups
            .entry((e.spec_key.clone(), e.n_clusters, e.routine.name()))
            .or_default()
            .push(e);
    }
    groups
        .into_iter()
        .map(|((spec_key, n_clusters, _), group)| {
            let n = group.len() as f64;
            let overheads: Vec<u64> = group.iter().map(|e| overhead_of(&e.trace)).collect();
            let executes = group
                .iter()
                .map(|e| e.trace.stats(Phase::Execute).map(|s| s.max).unwrap_or(0));
            Decomposition {
                spec_key,
                n_clusters,
                routine: group[0].routine,
                traces: group.len(),
                total_avg: group.iter().map(|e| e.trace.total as f64).sum::<f64>() / n,
                execute_avg: executes.map(|e| e as f64).sum::<f64>() / n,
                overhead_min: *overheads.iter().min().expect("non-empty group"),
                overhead_avg: overheads.iter().map(|&o| o as f64).sum::<f64>() / n,
                overhead_max: *overheads.iter().max().expect("non-empty group"),
            }
        })
        .collect()
}

/// Fig. 11-style per-phase min/avg/max bands for every scanned trace,
/// paired with its spec key — computed by the same
/// [`fig11::bands_of`] the figure itself uses, so a store holding the
/// paper grid reproduces `exp/fig11` bit-identically.
pub fn phase_bands(entries: &[StoredTrace]) -> Vec<(String, Band)> {
    let mut out = Vec::new();
    for e in entries {
        let mut bands = Vec::new();
        fig11::bands_of(&e.trace, e.routine, e.n_clusters, &mut bands);
        out.extend(bands.into_iter().map(|b| (e.spec_key.clone(), b)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::store::{self, TraceStore};
    use crate::config::Config;
    use crate::exp::CLUSTER_SWEEP;
    use crate::kernels::JobSpec;
    use crate::sweep::OffloadRequest;

    #[test]
    fn request_keys_parse_back_for_every_kernel_and_routine() {
        let specs = [
            JobSpec::Axpy { n: 1024 },
            JobSpec::MonteCarlo { samples: 4096 },
            JobSpec::Matmul { m: 16, n: 32, k: 8 },
            JobSpec::Atax { m: 64, n: 64 },
            JobSpec::Covariance { m: 32, n: 64 },
            JobSpec::Bfs { nodes: 64, levels: 4 },
        ];
        for spec in specs {
            for routine in RoutineKind::ALL {
                let req = OffloadRequest::new(spec, 8, routine);
                let key = store::request_key(&req);
                let (_, n, r) = parse_request_key(&key)
                    .unwrap_or_else(|| panic!("key {key} did not parse"));
                assert_eq!((n, r), (8, routine), "{key}");
            }
        }
        assert!(parse_request_key("config").is_none());
        assert!(parse_request_key("axpy_n1024-c0-multicast").is_none());
        assert!(parse_request_key("axpy_n1024-cX-multicast").is_none());
    }

    #[test]
    fn parse_request_key_round_trips_the_store_grammar() {
        // Property-style: pseudo-random sizes through every kernel shape
        // and the whole (clusters × routines) grid must invert exactly —
        // the spec half back to `JobSpec::store_id`, the rest to the
        // request's own fields. The grammar embeds `-c` and the sizes in
        // decimal, so nothing a spec can produce may confuse the split.
        let mut state: u64 = 0x2545_F491_4F6C_DD1D;
        let mut next = move |lo: u64, hi: u64| {
            // xorshift64*, deterministic across runs.
            state ^= state >> 12;
            state ^= state << 25;
            state ^= state >> 27;
            let r = state.wrapping_mul(0x2545_F491_4F6C_DD1D);
            lo + r % (hi - lo + 1)
        };
        for _ in 0..64 {
            let (a, b, c) = (next(1, 1 << 20), next(1, 4096), next(1, 4096));
            let specs = [
                JobSpec::Axpy { n: a as usize },
                JobSpec::MonteCarlo { samples: a as usize },
                JobSpec::Matmul { m: b as usize, n: c as usize, k: next(1, 512) as usize },
                JobSpec::Atax { m: b as usize, n: c as usize },
                JobSpec::Covariance { m: b as usize, n: c as usize },
                JobSpec::Bfs { nodes: b as usize, levels: next(1, 64) as usize },
            ];
            let n_clusters = next(1, 32) as usize;
            for spec in specs {
                for routine in RoutineKind::ALL {
                    let req = OffloadRequest::new(spec, n_clusters, routine);
                    let key = store::request_key(&req);
                    let (spec_key, n, r) = parse_request_key(&key)
                        .unwrap_or_else(|| panic!("key {key} did not parse"));
                    assert_eq!(spec_key, spec.store_id(), "{key}");
                    assert_eq!((n, r), (n_clusters, routine), "{key}");
                }
            }
        }
    }

    #[test]
    fn empty_and_config_only_stores_scan_to_zero_traces() {
        let dir = std::env::temp_dir().join(format!(
            "occamy-obs-report-empty-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // Brand-new store root: no fingerprint dirs at all.
        assert!(scan(&dir).unwrap().is_empty());
        // A fingerprint dir holding only the config sidecar and foreign
        // files scans clean too — nothing parses as a request key, and
        // none of it is an error.
        let fp = dir.join("0123456789abcdef");
        std::fs::create_dir_all(&fp).unwrap();
        std::fs::write(fp.join("config.json"), "{}").unwrap();
        std::fs::write(fp.join("not-a-key.json"), "{}").unwrap();
        std::fs::write(fp.join("x-c2-bogusroutine.json"), "{}").unwrap();
        std::fs::write(fp.join("README.txt"), "hi").unwrap();
        assert!(scan(&dir).unwrap().is_empty());
        // A missing root stays a hard error, hint intact.
        let err = scan(&dir.join("nope")).unwrap_err().to_string();
        assert!(err.contains("--store"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn store_report_reproduces_fig11_bit_identically() {
        // A config distinct from every other test's cache namespace.
        let mut cfg = Config::default();
        cfg.timing.host_ipi_issue_gap = 9401;
        let results = fig11::sweep().run(&cfg);
        let reference = fig11::from_results(&results);

        // Persist the whole grid the way a campaign/serve run would.
        let dir = std::env::temp_dir().join(format!(
            "occamy-obs-report-test-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let tstore = TraceStore::open(&dir).unwrap();
        let fp = store::fingerprint(&cfg);
        for rec in results.records() {
            tstore.save(&fp, &cfg, &rec.req(), &rec.trace).unwrap();
        }

        // Re-derive the figure purely from disk.
        let entries = scan(&dir).unwrap();
        assert_eq!(entries.len(), results.records().len());
        let axpy: Vec<StoredTrace> = entries
            .into_iter()
            .filter(|e| {
                e.spec_key == "axpy_n1024"
                    && matches!(e.routine, RoutineKind::Baseline | RoutineKind::Multicast)
            })
            .collect();
        let from_store = fig11::Fig11 {
            bands: phase_bands(&axpy).into_iter().map(|(_, b)| b).collect(),
        };
        for p in Phase::ALL {
            for routine in [RoutineKind::Baseline, RoutineKind::Multicast] {
                for &n in &CLUSTER_SWEEP {
                    let want = reference.get(p, routine, n);
                    let got = from_store.get(p, routine, n);
                    match (want, got) {
                        (None, None) => {}
                        (Some(w), Some(g)) => {
                            assert_eq!((w.min, w.max), (g.min, g.max), "{p:?} {routine:?} n={n}");
                            assert_eq!(
                                w.avg.to_bits(),
                                g.avg.to_bits(),
                                "{p:?} {routine:?} n={n}: avg {} vs {}",
                                w.avg,
                                g.avg
                            );
                        }
                        _ => panic!("band presence differs for {p:?} {routine:?} n={n}"),
                    }
                }
            }
        }

        // The decomposition covers the same grid, overhead + execute
        // summing back to the total for the single-trace groups.
        let rows = decompose(&axpy);
        assert_eq!(rows.len(), CLUSTER_SWEEP.len() * 2);
        for row in &rows {
            assert_eq!(row.traces, 1);
            assert!(
                (row.execute_avg + row.overhead_avg - row.total_avg).abs() < 1e-9,
                "decomposition must sum to total: {row:?}"
            );
            assert!(row.overhead_pct() > 0.0 && row.overhead_pct() < 100.0, "{row:?}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
