//! TCP front end: listener, sessions, graceful shutdown.
//!
//! One thread per session, all sessions serialized on the shared
//! [`Engine`] mutex — the engine is a deterministic virtual-time core,
//! so the mutex is held for microseconds per request (memoized lookups)
//! and only ever long for a fresh simulation. Replies are written before
//! the next line is read, so a session can never accumulate unanswered
//! requests: "drain in-flight work on shutdown" falls out of the
//! protocol's lockstep shape rather than needing a reaper.
//!
//! Robustness contract (tested in `tests/integration_serve.rs`): a
//! malformed line — torn JSON, garbage bytes, an unknown op — yields an
//! `error` reply on that session and nothing else. The listener and
//! every other session keep running. Only an explicit `shutdown` request
//! stops the daemon: it drains the virtual timeline, stops accepting,
//! unblocks every session, and [`Server::wait`] then joins them all
//! before reporting final stats.

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::Duration;

use super::engine::{Engine, EngineOptions};
use super::proto::{Request, StatsReply};

/// How long a blocked session read waits before re-checking the
/// shutdown flag.
const READ_POLL: Duration = Duration::from_millis(50);

/// A running serve daemon.
pub struct Server {
    addr: SocketAddr,
    engine: Arc<Mutex<Engine>>,
    shutdown: Arc<AtomicBool>,
    accept_thread: JoinHandle<()>,
    sessions: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

/// Lock an engine mutex, recovering from poisoning: a session that
/// panics mid-request leaves the engine consistent enough for metrics
/// and shutdown, and wedging every other session behind the poison flag
/// would turn one bad request into a daemon outage.
fn lock(engine: &Arc<Mutex<Engine>>) -> MutexGuard<'_, Engine> {
    engine.lock().unwrap_or_else(PoisonError::into_inner)
}

impl Server {
    /// Bind `listen` (e.g. `127.0.0.1:7077`, or port `0` for an
    /// OS-assigned port) and start accepting sessions.
    pub fn start(opts: EngineOptions, listen: &str) -> anyhow::Result<Server> {
        let engine = Arc::new(Mutex::new(Engine::new(opts)?));
        let listener =
            TcpListener::bind(listen).map_err(|e| anyhow::anyhow!("bind {listen}: {e}"))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let sessions: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));

        let accept_thread = {
            let engine = Arc::clone(&engine);
            let shutdown = Arc::clone(&shutdown);
            let sessions = Arc::clone(&sessions);
            std::thread::spawn(move || {
                // ordering: SeqCst — rare single-flag transition (one
                // store at shutdown, polled at accept/read timeouts);
                // the total order costs nothing here and spares every
                // reader a pairing argument.
                while !shutdown.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let eng = Arc::clone(&engine);
                            let stop = Arc::clone(&shutdown);
                            let handle = std::thread::spawn(move || session(stream, eng, stop));
                            sessions.lock().unwrap_or_else(PoisonError::into_inner).push(handle);
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => std::thread::sleep(Duration::from_millis(5)),
                    }
                }
            })
        };

        Ok(Server {
            addr,
            engine,
            shutdown,
            accept_thread,
            sessions,
        })
    }

    /// The actual bound address (resolves `:0` listens).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Print any summary line that came due (the CLI polls this).
    pub fn take_summary(&self) -> Option<String> {
        lock(&self.engine).take_summary()
    }

    /// True once a client has requested shutdown.
    pub fn is_shutting_down(&self) -> bool {
        // ordering: SeqCst — see the accept loop: one rare flag, total
        // order by policy.
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Block until a client requests shutdown, join every session (each
    /// finishes its in-flight request first), and return the final
    /// stats alongside the store counters.
    pub fn wait(self) -> (StatsReply, Option<crate::campaign::store::StoreStats>, String) {
        let _ = self.accept_thread.join();
        let mut held = self.sessions.lock().unwrap_or_else(PoisonError::into_inner);
        let handles = std::mem::take(&mut *held);
        drop(held);
        for h in handles {
            let _ = h.join();
        }
        let engine = lock(&self.engine);
        (engine.stats(), engine.store_stats(), engine.summary_line())
    }
}

/// One client session: read a line, answer it, repeat. Exits on EOF,
/// unrecoverable socket errors, or daemon shutdown.
fn session(stream: TcpStream, engine: Arc<Mutex<Engine>>, shutdown: Arc<AtomicBool>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let reader_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(reader_half);
    let mut writer = stream;
    // Bytes of the line being assembled. Kept across read timeouts so a
    // slow writer's partial line is never dropped.
    let mut buf: Vec<u8> = Vec::new();
    loop {
        match reader.read_until(b'\n', &mut buf) {
            Ok(0) => {
                // EOF. Answer a torn trailing line (no newline) so the
                // client-side error is observable, then close.
                if !buf.is_empty() {
                    let _ = answer(&buf, &mut writer, &engine, &shutdown);
                }
                return;
            }
            Ok(_) => {
                if buf.last() != Some(&b'\n') {
                    // EOF mid-line; answered on the next Ok(0) pass.
                    continue;
                }
                let done = answer(&buf, &mut writer, &engine, &shutdown);
                buf.clear();
                if done {
                    return;
                }
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                // ordering: SeqCst — same shutdown flag as the accept
                // loop; total order by policy.
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
            }
            Err(_) => return,
        }
    }
}

/// Process one raw line and write the reply. Returns `true` when the
/// session should end (shutdown acknowledged or the peer is gone).
fn answer(
    raw: &[u8],
    writer: &mut TcpStream,
    engine: &Arc<Mutex<Engine>>,
    shutdown: &Arc<AtomicBool>,
) -> bool {
    // Garbage bytes must produce an error reply, not kill the session:
    // decode lossily and let the JSON parser complain.
    let line = String::from_utf8_lossy(raw);
    let line = line.trim();
    if line.is_empty() {
        return false;
    }
    let parsed = Request::from_line(line);
    let is_shutdown = matches!(parsed, Ok(Request::Shutdown));
    let reply = match parsed {
        Ok(req) => lock(engine).handle(&req),
        Err(e) => lock(engine).protocol_error(format!("bad request: {e}")),
    };
    let ok = writer
        .write_all(format!("{}\n", reply.to_line()).as_bytes())
        .and_then(|()| writer.flush())
        .is_ok();
    if let Some(summary) = lock(engine).take_summary() {
        println!("{summary}");
    }
    if is_shutdown {
        // Stop the accept loop; other sessions notice on their next
        // read-timeout poll.
        // ordering: SeqCst — the single store of the shutdown flag; all
        // pollers use SeqCst, so every thread agrees on the transition.
        shutdown.store(true, Ordering::SeqCst);
        return true;
    }
    !ok
}
