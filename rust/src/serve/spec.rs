//! `serve.toml`: one file describing a daemon and a load-generator run.
//!
//! A deliberately small TOML subset (the same philosophy as
//! [`CampaignSpec`](crate::campaign::spec::CampaignSpec), parsed with the
//! same line discipline): two tables, scalar and string-array values,
//! `#` comments, and hard errors on anything unrecognized — a typo in an
//! SLO should fail loudly, not silently serve with defaults. Every field
//! is optional; the CLI overlays its own flags on top, so the file is a
//! baseline, not a cage.

use std::path::PathBuf;

use crate::offload::RoutineKind;
use crate::sim::SimProfile;

use super::engine::EngineOptions;
use super::loadgen::{ArrivalKind, LoadgenOptions};

/// Parsed `[serve]` table: daemon-side knobs.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServeSection {
    pub inflight: Option<usize>,
    pub queue_factor: Option<usize>,
    /// Default arrival gap for submissions that carry none.
    pub gap: Option<u64>,
    pub slo_cycles: Option<u64>,
    pub summary_every: Option<u64>,
    /// Trace-store root (relative paths resolve against the CWD).
    pub store: Option<String>,
    /// Structured JSONL event-log path ([`crate::obs::log`]); the CLI's
    /// `--log` flag overrides it.
    pub log: Option<String>,
    /// Engine profile (`"reference"` or `"fast"`).
    pub profile: Option<SimProfile>,
}

/// Parsed `[loadgen]` table: client-side traffic description.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LoadgenSection {
    pub process: Option<ArrivalKind>,
    pub requests: Option<u64>,
    pub mean_gap: Option<u64>,
    pub burst: Option<u64>,
    pub period: Option<u64>,
    pub seed: Option<u64>,
    pub mix: Option<Vec<String>>,
    pub clusters: Option<usize>,
    pub routine: Option<RoutineKind>,
}

/// A parsed `serve.toml`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServeSpec {
    pub serve: ServeSection,
    pub loadgen: LoadgenSection,
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless it sits inside a double-quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_string(v: &str, key: &str) -> Result<String, String> {
    let v = v.trim();
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        Ok(v[1..v.len() - 1].to_string())
    } else {
        Err(format!("{key} wants a double-quoted string, got {v:?}"))
    }
}

fn parse_u64(v: &str, key: &str) -> Result<u64, String> {
    v.trim()
        .parse::<u64>()
        .map_err(|_| format!("{key} wants a non-negative integer, got {:?}", v.trim()))
}

fn parse_string_array(v: &str, key: &str) -> Result<Vec<String>, String> {
    let v = v.trim();
    let inner = v
        .strip_prefix('[')
        .and_then(|s| s.strip_suffix(']'))
        .ok_or_else(|| format!("{key} wants a [\"..\", ..] array, got {v:?}"))?;
    let inner = inner.trim();
    if inner.is_empty() {
        return Ok(Vec::new());
    }
    inner.split(',').map(|e| parse_string(e, key)).collect()
}

impl ServeSpec {
    pub fn parse(text: &str) -> Result<ServeSpec, String> {
        #[derive(PartialEq)]
        enum Section {
            None,
            Serve,
            Loadgen,
        }
        let mut spec = ServeSpec::default();
        let mut section = Section::None;
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            let at = |e: String| format!("serve.toml line {}: {e}", lineno + 1);
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = match name.trim() {
                    "serve" => Section::Serve,
                    "loadgen" => Section::Loadgen,
                    other => {
                        return Err(at(format!(
                            "unknown section [{other}] (expected [serve] or [loadgen])"
                        )))
                    }
                };
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| at(format!("expected key = value, got {line:?}")))?;
            let (key, value) = (key.trim(), value.trim());
            match section {
                Section::None => {
                    return Err(at(format!(
                        "key {key:?} before any section (expected [serve] or [loadgen])"
                    )))
                }
                Section::Serve => match key {
                    "inflight" => {
                        spec.serve.inflight = Some(parse_u64(value, key).map_err(at)? as usize)
                    }
                    "queue_factor" => {
                        spec.serve.queue_factor = Some(parse_u64(value, key).map_err(at)? as usize)
                    }
                    "gap" => spec.serve.gap = Some(parse_u64(value, key).map_err(at)?),
                    "slo_cycles" => {
                        spec.serve.slo_cycles = Some(parse_u64(value, key).map_err(at)?)
                    }
                    "summary_every" => {
                        spec.serve.summary_every = Some(parse_u64(value, key).map_err(at)?)
                    }
                    "store" => spec.serve.store = Some(parse_string(value, key).map_err(at)?),
                    "log" => spec.serve.log = Some(parse_string(value, key).map_err(at)?),
                    "profile" => {
                        let name = parse_string(value, key).map_err(at)?;
                        let profile = SimProfile::parse(&name).ok_or_else(|| {
                            at(format!(
                                "unknown profile {name:?} (expected \"reference\" or \"fast\")"
                            ))
                        })?;
                        spec.serve.profile = Some(profile);
                    }
                    other => return Err(at(format!("unknown [serve] key {other:?}"))),
                },
                Section::Loadgen => match key {
                    "process" => {
                        let name = parse_string(value, key).map_err(at)?;
                        match ArrivalKind::parse(&name) {
                            Some(kind) => spec.loadgen.process = Some(kind),
                            None => {
                                return Err(at(format!(
                                    "unknown process {name:?} (poisson, bursty, diurnal or fixed)"
                                )))
                            }
                        }
                    }
                    "requests" => spec.loadgen.requests = Some(parse_u64(value, key).map_err(at)?),
                    "mean_gap" => spec.loadgen.mean_gap = Some(parse_u64(value, key).map_err(at)?),
                    "burst" => spec.loadgen.burst = Some(parse_u64(value, key).map_err(at)?),
                    "period" => spec.loadgen.period = Some(parse_u64(value, key).map_err(at)?),
                    "seed" => spec.loadgen.seed = Some(parse_u64(value, key).map_err(at)?),
                    "mix" => spec.loadgen.mix = Some(parse_string_array(value, key).map_err(at)?),
                    "clusters" => {
                        spec.loadgen.clusters = Some(parse_u64(value, key).map_err(at)? as usize)
                    }
                    "routine" => {
                        let name = parse_string(value, key).map_err(at)?;
                        let routine = RoutineKind::parse(&name)
                            .ok_or_else(|| at(format!("unknown routine {name:?}")))?;
                        spec.loadgen.routine = Some(routine);
                    }
                    other => return Err(at(format!("unknown [loadgen] key {other:?}"))),
                },
            }
        }
        // Validate early what the engine would reject late.
        for tok in spec.loadgen.mix.as_deref().unwrap_or(&[]) {
            crate::campaign::spec::parse_kernel(tok)
                .map_err(|e| format!("serve.toml mix entry {tok:?}: {e}"))?;
        }
        Ok(spec)
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<ServeSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        ServeSpec::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }

    /// Overlay the `[serve]` table onto engine defaults. CLI flags are
    /// applied by the caller after this, so precedence is
    /// defaults < file < flags.
    pub fn engine_options(&self, base: EngineOptions) -> EngineOptions {
        let mut opts = base;
        if let Some(v) = self.serve.inflight {
            opts.inflight = v;
        }
        if let Some(v) = self.serve.queue_factor {
            opts.queue_factor = v;
        }
        if let Some(v) = self.serve.gap {
            opts.default_gap = v;
        }
        if let Some(v) = self.serve.slo_cycles {
            opts.slo_cycles = v;
        }
        if let Some(v) = self.serve.summary_every {
            opts.summary_every = v;
        }
        if let Some(v) = &self.serve.store {
            opts.store_root = Some(PathBuf::from(v));
        }
        if let Some(v) = self.serve.profile {
            opts.profile = v;
        }
        opts
    }

    /// Overlay the `[loadgen]` table onto loadgen defaults.
    pub fn loadgen_options(&self, base: LoadgenOptions) -> LoadgenOptions {
        let mut opts = base;
        if let Some(v) = self.loadgen.process {
            opts.kind = v;
        }
        if let Some(v) = self.loadgen.requests {
            opts.requests = v;
        }
        if let Some(v) = self.loadgen.mean_gap {
            opts.mean_gap = v;
        }
        if let Some(v) = self.loadgen.burst {
            opts.burst = v;
        }
        if let Some(v) = self.loadgen.period {
            opts.period = v;
        }
        if let Some(v) = self.loadgen.seed {
            opts.seed = v;
        }
        if let Some(v) = &self.loadgen.mix {
            opts.mix = v.clone();
        }
        if let Some(v) = self.loadgen.clusters {
            opts.clusters = Some(v);
        }
        if let Some(v) = self.loadgen.routine {
            opts.routine = Some(v);
        }
        opts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: &str = r#"
# A daemon plus a matching traffic description.
[serve]
inflight = 8
queue_factor = 2
gap = 25000
slo_cycles = 2000000   # 2M cycles end-to-end
summary_every = 64
store = "serve-store"
log = "serve-events.jsonl"
profile = "fast"

[loadgen]
process = "bursty"
requests = 512
mean_gap = 25000
burst = 16
period = 8000000
seed = 99
mix = ["axpy:1024", "montecarlo:4096"]  # uniform over these
clusters = 8
routine = "multicast"
"#;

    #[test]
    fn full_spec_parses_and_overlays() {
        let spec = ServeSpec::parse(FULL).unwrap();
        let e = spec.engine_options(EngineOptions::default());
        assert_eq!((e.inflight, e.queue_factor), (8, 2));
        assert_eq!((e.default_gap, e.slo_cycles, e.summary_every), (25_000, 2_000_000, 64));
        assert_eq!(e.store_root, Some(PathBuf::from("serve-store")));
        assert_eq!(e.profile, SimProfile::Fast);
        // `log` is CLI-side (the daemon installs the global sink before
        // the engine exists), so it rides on the section, not the
        // engine options.
        assert_eq!(spec.serve.log.as_deref(), Some("serve-events.jsonl"));
        let l = spec.loadgen_options(LoadgenOptions::default());
        assert_eq!(l.kind, ArrivalKind::Bursty);
        assert_eq!(
            (l.requests, l.mean_gap, l.burst, l.period, l.seed),
            (512, 25_000, 16, 8_000_000, 99)
        );
        assert_eq!(l.mix, vec!["axpy:1024".to_string(), "montecarlo:4096".to_string()]);
        assert_eq!(l.clusters, Some(8));
        assert_eq!(l.routine, Some(RoutineKind::Multicast));
    }

    #[test]
    fn empty_spec_changes_nothing() {
        let spec = ServeSpec::parse("").unwrap();
        let base = EngineOptions::default();
        let e = spec.engine_options(base.clone());
        assert_eq!((e.inflight, e.queue_factor), (base.inflight, base.queue_factor));
        let l = spec.loadgen_options(LoadgenOptions::default());
        assert_eq!(l.requests, LoadgenOptions::default().requests);
    }

    #[test]
    fn unknown_keys_and_sections_fail_loudly() {
        for (text, needle) in [
            ("[serve]\nslo = 5\n", "unknown [serve] key"),
            ("[loadgen]\nrate = 5\n", "unknown [loadgen] key"),
            ("[daemon]\n", "unknown section"),
            ("inflight = 4\n", "before any section"),
            ("[serve]\ninflight\n", "expected key = value"),
            ("[serve]\ninflight = \"four\"\n", "non-negative integer"),
            ("[loadgen]\nprocess = \"sawtooth\"\n", "unknown process"),
            ("[loadgen]\nroutine = \"warp\"\n", "unknown routine"),
            ("[serve]\nprofile = \"warp\"\n", "unknown profile"),
            ("[loadgen]\nmix = [\"frobnicate:9\"]\n", "mix entry"),
        ] {
            let err = ServeSpec::parse(text).unwrap_err();
            assert!(err.contains(needle), "{text:?} -> {err}");
        }
    }

    #[test]
    fn comments_do_not_leak_into_values() {
        let spec = ServeSpec::parse("[serve]\nstore = \"a # b\" # trailing\n").unwrap();
        assert_eq!(spec.serve.store.as_deref(), Some("a # b"));
    }
}
