//! The daemon's core: one request in, one reply out, deterministically.
//!
//! # Scheduling
//! Jobs are scheduled through the same [`OccupancyModel`] the batch
//! coordinator uses, but driven *open-loop*: every submission carries a
//! virtual inter-arrival gap (sampled by the load generator's traffic
//! process), the engine advances its arrival clock by that gap, and the
//! job enters the model at the clock via
//! [`OccupancyModel::admit_at`]. Because the timeline is virtual, the
//! whole schedule is a pure function of the request sequence — identical
//! bursts produce identical latencies regardless of wall-clock timing,
//! which is what makes serve runs reproducible benchmarks rather than
//! load-dependent noise.
//!
//! # Admission control
//! The bounded queue is `inflight * queue_factor` jobs outstanding on
//! the virtual timeline (admitted, not yet completed by the current
//! arrival instant). A submission that finds the queue full gets an
//! immediate `rejected: overloaded` reply — never a blocking wait — so
//! an overload sheds load visibly instead of growing queueing delay
//! without bound. Jobs inside the bound still queue (for the window, a
//! JCU slot, or clusters) and that wait is reported per request.
//!
//! # Memoization
//! Service cycles come from the same three-tier lookup campaigns use:
//! process-wide trace cache, then the on-disk [`TraceStore`], then a
//! fresh DES run (persisted back). A warm store answers every request
//! with zero fresh simulations — the `stats` verb exposes the counter
//! that proves it.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::path::PathBuf;
use std::sync::Arc;

use crate::campaign::store::{self, TraceStore};
use crate::campaign::stream::Source;
use crate::config::Config;
use crate::coordinator::{OccupancyModel, OccupancyParams, Placement, Planner, JCU_SLOTS};
use crate::offload::RoutineKind;
use crate::sim::{fast, SimProfile, Time};
use crate::sweep::{cache, OffloadRequest};

use crate::obs::log::{self as obslog, Event, Level};
use crate::obs::metrics::{register_log_stats, register_store_stats, Registry};
use crate::obs::span::{self, TraceContext};
use crate::obs::flight;

use super::metrics::ServeMetrics;
use super::proto::{ErrorReply, JobReply, MetricsReply, Rejected, Reply, Request, StatsReply, Submit};

/// Configuration of one engine (and daemon) instance.
#[derive(Debug, Clone)]
pub struct EngineOptions {
    pub cfg: Config,
    /// Closed-loop window of the occupancy model (how many jobs may be
    /// dispatch-eligible at once).
    pub inflight: usize,
    /// Admission bound = `inflight * queue_factor` jobs outstanding.
    pub queue_factor: usize,
    /// Default arrival gap for submissions that carry none.
    pub default_gap: Time,
    /// Latency SLO in virtual cycles.
    pub slo_cycles: u64,
    /// Trace-store root; `None` keeps memoization process-local.
    pub store_root: Option<PathBuf>,
    /// Print a summary line every N completions (0 = only at shutdown).
    pub summary_every: u64,
    /// Engine profile behind `service_cycles`. The fast profile is
    /// bit-identical to the reference DES (see `sim::fast`); fast runs
    /// still keep their process-cache entries under a separate key, and
    /// traces are verified against the reference before any disk
    /// persist.
    pub profile: SimProfile,
}

impl Default for EngineOptions {
    fn default() -> Self {
        Self {
            cfg: Config::default(),
            inflight: 4,
            queue_factor: 4,
            default_gap: 0,
            slo_cycles: 1_000_000,
            store_root: None,
            summary_every: 0,
            profile: SimProfile::Reference,
        }
    }
}

/// The serve daemon's single-threaded core. Sessions serialize on it; a
/// request's reply depends only on the engine state and the request
/// sequence so far.
pub struct Engine {
    cfg: Config,
    fp: String,
    mem_key: String,
    store: Option<TraceStore>,
    model: OccupancyModel,
    metrics: ServeMetrics,
    /// Open-loop arrival clock (virtual cycles).
    clock: Time,
    /// Completion times of admitted jobs not yet retired by the clock.
    outstanding: BinaryHeap<Reverse<Time>>,
    queue_bound: usize,
    default_gap: Time,
    summary_every: u64,
    summary_due: bool,
    profile: SimProfile,
    /// Admission sequence number for accelerator placements — the
    /// deterministic half of every span id.
    seq: u64,
    /// One flight dump per overload burst, not one per shed request.
    shed_dumped: bool,
}

impl Engine {
    pub fn new(opts: EngineOptions) -> anyhow::Result<Self> {
        anyhow::ensure!(opts.inflight >= 1, "inflight must be >= 1");
        anyhow::ensure!(opts.queue_factor >= 1, "queue-factor must be >= 1");
        if let Some(root) = &opts.store_root {
            flight::set_dump_dir(&root.join("flight"));
            flight::install_panic_hook();
        }
        // Config banner: serve-report uses it as the group delimiter
        // when several daemon logs are concatenated. Not a span.
        obslog::emit(
            &Event::sim("serve", "engine_start", 0)
                .u64("inflight", opts.inflight as u64)
                .u64("queue_factor", opts.queue_factor as u64)
                .u64("gap", opts.default_gap)
                .str("profile", opts.profile.name()),
        );
        let store = opts.store_root.map(TraceStore::open).transpose()?;
        let fp = store::fingerprint(&opts.cfg);
        let mem_key = cache::profiled_config_key(&opts.cfg, opts.profile);
        let model = OccupancyModel::new(OccupancyParams {
            capacity: opts.cfg.soc.n_clusters(),
            jcu_slots: JCU_SLOTS,
            inflight: opts.inflight,
            arrival_gap: 0,
        });
        Ok(Self {
            cfg: opts.cfg,
            fp,
            mem_key,
            store,
            model,
            metrics: ServeMetrics::new(opts.slo_cycles),
            clock: 0,
            outstanding: BinaryHeap::new(),
            queue_bound: opts.inflight * opts.queue_factor,
            default_gap: opts.default_gap,
            summary_every: opts.summary_every,
            summary_due: false,
            profile: opts.profile,
            seq: 0,
            shed_dumped: false,
        })
    }

    /// Handle one request. Every variant answers; `Shutdown` also drains
    /// the virtual timeline (the session layer closes the listener).
    pub fn handle(&mut self, req: &Request) -> Reply {
        match req {
            Request::Submit(s) => self.submit(s),
            Request::Stats => Reply::Stats(self.stats()),
            Request::Metrics => Reply::Metrics(MetricsReply {
                text: self.prometheus(),
            }),
            Request::Ping => Reply::Pong,
            Request::Shutdown => Reply::ShuttingDown {
                drained: self.drain(),
            },
        }
    }

    /// Record a protocol-level failure (unparseable line) and build the
    /// error reply for it.
    pub fn protocol_error(&mut self, message: String) -> Reply {
        self.metrics.record_error();
        Reply::Error(ErrorReply { id: None, message })
    }

    fn error(&mut self, id: u64, message: String) -> Reply {
        self.metrics.record_error();
        if obslog::enabled() {
            obslog::emit(
                &Event::sim("serve", "error", self.clock)
                    .level(Level::Warn)
                    .u64("id", id)
                    .str("message", &message),
            );
        }
        Reply::Error(ErrorReply {
            id: Some(id),
            message,
        })
    }

    fn submit(&mut self, s: &Submit) -> Reply {
        let spec = match crate::campaign::spec::parse_kernel(&s.kernel) {
            Ok(spec) => spec,
            Err(e) => return self.error(s.id, e),
        };
        let capacity = self.model.params().capacity;
        if let Some(n) = s.clusters {
            if n == 0 || n > capacity {
                return self.error(
                    s.id,
                    format!("clusters must be in 1..={capacity} (the SoC geometry), got {n}"),
                );
            }
        }

        // Advance the open-loop arrival clock, then retire everything
        // the fabric finished before this arrival.
        let gap = s.gap.unwrap_or(self.default_gap);
        self.clock = self.clock.saturating_add(gap);
        while let Some(&Reverse(c)) = self.outstanding.peek() {
            if c > self.clock {
                break;
            }
            self.outstanding.pop();
        }

        // Admission control: the bounded queue. Full → shed, visibly.
        if self.outstanding.len() >= self.queue_bound {
            self.metrics.record_rejection();
            let ev = Event::sim("serve", "reject", self.clock)
                .level(Level::Warn)
                .u64("id", s.id)
                .str("kernel", &s.kernel)
                .u64("backlog", self.outstanding.len() as u64)
                .u64("bound", self.queue_bound as u64);
            flight::note(&ev.render());
            if obslog::enabled() {
                obslog::emit(&ev);
            }
            // First shed of a burst dumps the flight ring: the requests
            // leading into the overload are exactly the post-mortem.
            if !self.shed_dumped {
                self.shed_dumped = true;
                flight::dump("overload");
            }
            return Reply::Rejected(Rejected {
                id: s.id,
                reason: "overloaded".into(),
                backlog: self.outstanding.len() as u64,
                bound: self.queue_bound as u64,
            });
        }

        let planner = Planner::new(&self.cfg);
        let routine = s.routine.unwrap_or(RoutineKind::Multicast);
        let placement = match s.clusters {
            Some(n) => Placement::Accelerator { n_clusters: n },
            None => planner.plan(&spec).placement,
        };
        match placement {
            Placement::Host => {
                // Host jobs run on CVA6 outside the fabric's dispatch
                // window — no simulation, no queueing (mirrors the batch
                // coordinator's host path).
                let cycles = planner.host_estimate(&spec);
                self.metrics.record_host(cycles);
                if obslog::enabled() {
                    obslog::emit(
                        &Event::sim("serve", "host_place", self.clock)
                            .u64("id", s.id)
                            .str("kernel", &s.kernel)
                            .u64("cycles", cycles),
                    );
                }
                self.after_completion();
                Reply::Result(JobReply {
                    id: s.id,
                    kernel: s.kernel.clone(),
                    placement,
                    routine,
                    cycles,
                    queue_delay: 0,
                    latency: cycles,
                    start: self.clock,
                    completion: self.clock + cycles,
                    source: None,
                    hit: false,
                })
            }
            Placement::Accelerator { n_clusters } => {
                let req = OffloadRequest::new(spec, n_clusters, routine);
                let arrival = self.clock;
                if obslog::enabled() {
                    obslog::emit(
                        &Event::sim("serve", "accept", arrival)
                            .u64("id", s.id)
                            .str("kernel", &s.kernel)
                            .u64("clusters", n_clusters as u64)
                            .str("routine", routine.name()),
                    );
                }
                let (service, source) = self.service_cycles(req);
                let adm = self.model.admit_at(arrival, n_clusters, service);
                self.outstanding.push(Reverse(adm.completion));
                // End-to-end wait from the *open-loop* arrival, which
                // includes any window-floor deferral the model applied.
                let queue_delay = adm.start - arrival;
                self.metrics.record_accel(service, queue_delay, source);
                self.shed_dumped = false;

                // Span tree for this request: derived ids only — the
                // submit's traceparent (when present) parents the
                // request span; otherwise the request roots its own
                // trace, so server-only logs still form complete trees.
                let seq = self.seq;
                self.seq += 1;
                let span_key = format!("{}|c{}|{}", s.kernel, n_clusters, routine.name());
                let (ctx, parent) = match s.traceparent.as_deref().and_then(TraceContext::parse) {
                    Some(tp) => (tp.child(&span_key, seq), Some(tp.span)),
                    None => (span::self_rooted(&self.fp, &span_key, seq), None),
                };
                let request_span = span::sim_span(
                    "request",
                    ctx,
                    parent,
                    arrival,
                    adm.completion - arrival,
                )
                .u64("id", s.id)
                .str("kernel", &s.kernel)
                .u64("clusters", n_clusters as u64)
                .str("routine", routine.name())
                .u64("seq", seq)
                .u64("gap", gap);
                flight::note(&request_span.render());
                if obslog::enabled() {
                    let tier = match source {
                        Source::Mem => "hit_mem",
                        Source::Disk => "hit_disk",
                        Source::Sim => "fresh_sim",
                    };
                    obslog::emit(
                        &Event::sim("serve", tier, arrival)
                            .u64("id", s.id)
                            .u64("cycles", service),
                    );
                    obslog::emit(
                        &Event::sim("serve", "dispatch", adm.start)
                            .u64("id", s.id)
                            .u64("queue_delay", queue_delay),
                    );
                    obslog::emit(
                        &Event::sim("serve", "complete", adm.completion)
                            .u64("id", s.id)
                            .u64("latency", service + queue_delay),
                    );
                    obslog::emit(&request_span);
                    let queue_ctx = TraceContext {
                        trace: ctx.trace,
                        span: span::child_span(ctx.span, "queue"),
                    };
                    obslog::emit(
                        &span::sim_span("queue", queue_ctx, Some(ctx.span), arrival, queue_delay)
                            .u64("id", s.id),
                    );
                    let exec_ctx = TraceContext {
                        trace: ctx.trace,
                        span: span::child_span(ctx.span, "execute"),
                    };
                    obslog::emit(
                        &span::sim_span("execute", exec_ctx, Some(ctx.span), adm.start, service)
                            .u64("id", s.id)
                            .str("source", source.name()),
                    );
                }
                self.after_completion();
                Reply::Result(JobReply {
                    id: s.id,
                    kernel: s.kernel.clone(),
                    placement,
                    routine,
                    cycles: service,
                    queue_delay,
                    latency: service + queue_delay,
                    start: adm.start,
                    completion: adm.completion,
                    source: Some(source),
                    hit: source.is_hit(),
                })
            }
        }
    }

    /// Service cycles for one offload, through the memoization tiers.
    fn service_cycles(&mut self, req: OffloadRequest) -> (Time, Source) {
        if let Some(store) = &self.store {
            let (trace, source) =
                store.run_sourced_profiled(&self.fp, &self.mem_key, &self.cfg, req, self.profile);
            (trace.total, source)
        } else if let Some(t) = cache::peek(&self.mem_key, req) {
            (t.total, Source::Mem)
        } else {
            let t = cache::insert(&self.mem_key, req, Arc::new(req.run_with(&self.cfg, self.profile)));
            (t.total, Source::Sim)
        }
    }

    fn after_completion(&mut self) {
        if self.summary_every > 0 && self.metrics.completed % self.summary_every == 0 {
            self.summary_due = true;
        }
    }

    /// A periodic summary line, if one came due since the last poll.
    pub fn take_summary(&mut self) -> Option<String> {
        if std::mem::take(&mut self.summary_due) {
            Some(self.metrics.summary_line())
        } else {
            None
        }
    }

    /// The metrics snapshot behind the `stats` verb.
    pub fn stats(&self) -> StatsReply {
        let mut s = self.metrics.snapshot();
        s.profile = self.profile.name().to_string();
        s
    }

    /// The Prometheus text exposition behind the `metrics` verb: every
    /// serve counter/distribution, plus the trace store's three-tier
    /// counters when a store is attached, plus the fast engine's
    /// process-wide elision counters when this daemon runs the fast
    /// profile.
    pub fn prometheus(&self) -> String {
        let mut r = Registry::new();
        self.metrics.register(&mut r);
        if let Some(stats) = self.store_stats() {
            register_store_stats(&mut r, &stats);
        }
        if self.profile == SimProfile::Fast {
            crate::obs::metrics::register_fast_stats(&mut r, &fast::stats());
        }
        register_log_stats(&mut r);
        r.render()
    }

    /// The final summary line (shutdown).
    pub fn summary_line(&self) -> String {
        self.metrics.summary_line()
    }

    /// Trace-store counters, when a store is attached.
    pub fn store_stats(&self) -> Option<crate::campaign::store::StoreStats> {
        self.store.as_ref().map(TraceStore::stats)
    }

    /// Drain the virtual timeline: retire every in-flight job (with full
    /// JCU interrupt bookkeeping) and return how many were still
    /// outstanding. Part of graceful shutdown.
    pub fn drain(&mut self) -> u64 {
        let drained = self.outstanding.len() as u64;
        self.outstanding.clear();
        self.model.finish();
        drained
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Unique timing offset per test so the process-wide cache and any
    /// store fingerprints never alias across parallel tests (the
    /// campaign test idiom).
    fn cfg_with_gap(gap: u64) -> Config {
        let mut cfg = Config::default();
        cfg.timing.host_ipi_issue_gap = gap;
        cfg
    }

    fn submit(id: u64, kernel: &str, clusters: usize, gap: u64) -> Submit {
        Submit {
            id,
            kernel: kernel.into(),
            clusters: Some(clusters),
            routine: Some(RoutineKind::Multicast),
            gap: Some(gap),
            seed: None,
            traceparent: None,
        }
    }

    #[test]
    fn identical_request_sequences_reply_identically() {
        let opts = EngineOptions {
            cfg: cfg_with_gap(9301),
            ..EngineOptions::default()
        };
        // Prime the process-wide cache so both runs see the same
        // memoization state (otherwise the first run's inserts would
        // turn the second run's misses into hits).
        let mut warm = Engine::new(opts.clone()).unwrap();
        for i in 0..6 {
            warm.handle(&Request::Submit(submit(i, "axpy:512", 4, i * 50)));
        }
        let mut a = Engine::new(opts.clone()).unwrap();
        let mut b = Engine::new(opts).unwrap();
        for i in 0..6 {
            let s = submit(i, "axpy:512", 4, i * 50);
            assert_eq!(a.handle(&Request::Submit(s.clone())), b.handle(&Request::Submit(s)));
        }
        assert_eq!(a.stats(), b.stats());
    }

    #[test]
    fn service_cycles_match_the_isolated_des() {
        let cfg = cfg_with_gap(9303);
        let req = OffloadRequest::new(
            crate::kernels::JobSpec::Axpy { n: 640 },
            4,
            RoutineKind::Multicast,
        );
        let isolated = req.run(&cfg).total;
        let mut e = Engine::new(EngineOptions {
            cfg,
            ..EngineOptions::default()
        })
        .unwrap();
        match e.handle(&Request::Submit(submit(1, "axpy:640", 4, 0))) {
            Reply::Result(r) => {
                assert_eq!(r.cycles, isolated);
                assert_eq!(r.latency, r.cycles + r.queue_delay);
                assert_eq!(r.completion, r.start + r.cycles);
            }
            other => panic!("expected result, got {other:?}"),
        }
    }

    #[test]
    fn overload_rejects_instead_of_hanging() {
        // inflight 1, factor 1: one job outstanding is the bound. A
        // burst at gap 0 keeps the clock at 0, so nothing ever retires
        // and every job after the first is shed.
        let mut e = Engine::new(EngineOptions {
            cfg: cfg_with_gap(9305),
            inflight: 1,
            queue_factor: 1,
            ..EngineOptions::default()
        })
        .unwrap();
        let first = e.handle(&Request::Submit(submit(0, "axpy:512", 4, 0)));
        assert!(matches!(first, Reply::Result(_)), "{first:?}");
        for i in 1..4 {
            match e.handle(&Request::Submit(submit(i, "axpy:512", 4, 0))) {
                Reply::Rejected(r) => {
                    assert_eq!(r.reason, "overloaded");
                    assert_eq!((r.id, r.backlog, r.bound), (i, 1, 1));
                }
                other => panic!("expected rejection, got {other:?}"),
            }
        }
        assert_eq!(e.stats().rejected, 3);
        // Once the clock passes the first job's completion, admission
        // reopens.
        let reply = e.handle(&Request::Submit(submit(9, "axpy:512", 4, u32::MAX as u64)));
        assert!(matches!(reply, Reply::Result(_)), "{reply:?}");
    }

    #[test]
    fn repeats_hit_the_memoization_tier() {
        let mut e = Engine::new(EngineOptions {
            cfg: cfg_with_gap(9307),
            ..EngineOptions::default()
        })
        .unwrap();
        let miss = e.handle(&Request::Submit(submit(0, "axpy:768", 8, 0)));
        let hit = e.handle(&Request::Submit(submit(1, "axpy:768", 8, 1_000_000)));
        match (&miss, &hit) {
            (Reply::Result(m), Reply::Result(h)) => {
                assert!(!m.hit, "first request simulates: {m:?}");
                assert!(h.hit, "repeat is a memory hit: {h:?}");
                assert_eq!(m.cycles, h.cycles, "memoization is transparent");
            }
            other => panic!("expected two results, got {other:?}"),
        }
        let s = e.stats();
        assert_eq!((s.fresh_sims, s.hits), (1, 1));
    }

    #[test]
    fn bad_requests_answer_errors_and_count_them() {
        let mut e = Engine::new(EngineOptions {
            cfg: cfg_with_gap(9309),
            ..EngineOptions::default()
        })
        .unwrap();
        for (id, kernel, clusters) in
            [(1, "frobnicate:64", 4), (2, "axpy:", 4), (3, "axpy:64", 0), (4, "axpy:64", 33)]
        {
            let s = Submit {
                id,
                kernel: kernel.into(),
                clusters: Some(clusters),
                routine: None,
                gap: None,
                seed: None,
                traceparent: None,
            };
            match e.handle(&Request::Submit(s)) {
                Reply::Error(err) => assert_eq!(err.id, Some(id)),
                other => panic!("expected error, got {other:?}"),
            }
        }
        assert_eq!(e.stats().errors, 4);
        // Errors never touched the timeline.
        assert_eq!(e.stats().completed, 0);
        assert!(matches!(e.handle(&Request::Ping), Reply::Pong));
    }

    #[test]
    fn planner_places_tiny_jobs_on_the_host() {
        let mut e = Engine::new(EngineOptions {
            cfg: cfg_with_gap(9311),
            ..EngineOptions::default()
        })
        .unwrap();
        let s = Submit {
            id: 1,
            kernel: "axpy:16".into(),
            clusters: None,
            routine: None,
            gap: None,
            seed: None,
            traceparent: None,
        };
        match e.handle(&Request::Submit(s)) {
            Reply::Result(r) => {
                assert_eq!(r.placement, Placement::Host);
                assert_eq!(r.queue_delay, 0);
                assert_eq!(r.source, None);
            }
            other => panic!("expected result, got {other:?}"),
        }
        assert_eq!(e.stats().host_placements, 1);
    }

    #[test]
    fn metrics_verb_answers_prometheus_text() {
        let mut e = Engine::new(EngineOptions {
            cfg: cfg_with_gap(9315),
            ..EngineOptions::default()
        })
        .unwrap();
        e.handle(&Request::Submit(submit(1, "axpy:896", 4, 0)));
        let reply = e.handle(&Request::Metrics);
        let Reply::Metrics(m) = reply else {
            panic!("expected metrics, got {reply:?}");
        };
        assert!(
            m.text.contains("occamy_serve_requests_total{outcome=\"completed\"} 1\n"),
            "{}",
            m.text
        );
        assert!(m.text.contains("# TYPE occamy_serve_latency_cycles histogram\n"), "{}", m.text);
        // No store attached: the store families are absent, not zero.
        assert!(!m.text.contains("occamy_store_"), "{}", m.text);
        // The reply survives the wire (newline-heavy text as one line).
        let line = Reply::Metrics(m.clone()).to_line();
        assert_eq!(Reply::from_line(&line).unwrap(), Reply::Metrics(m));
    }

    #[test]
    fn event_log_records_the_request_lifecycle() {
        // First init wins process-wide; either way the sink is live.
        crate::obs::log::init(crate::obs::log::EventLog::in_memory());
        let mut e = Engine::new(EngineOptions {
            cfg: cfg_with_gap(9317),
            inflight: 1,
            queue_factor: 1,
            ..EngineOptions::default()
        })
        .unwrap();
        // Ids unique to this test: other tests' events share the ring.
        e.handle(&Request::Submit(submit(987_001, "axpy:960", 4, 0)));
        e.handle(&Request::Submit(submit(987_002, "axpy:960", 4, 0)));
        let mine: Vec<String> = crate::obs::log::recent()
            .into_iter()
            .filter(|l| l.contains("\"id\":987"))
            .collect();
        let has = |id: u64, ev: &str| {
            mine.iter().any(|l| {
                l.contains(&format!("\"id\":{id}")) && l.contains(&format!("\"event\":\"{ev}\""))
            })
        };
        assert!(has(987_001, "accept"), "{mine:?}");
        assert!(has(987_001, "fresh_sim"), "{mine:?}");
        assert!(has(987_001, "dispatch"), "{mine:?}");
        assert!(has(987_001, "complete"), "{mine:?}");
        assert!(has(987_002, "reject"), "second job overflows the bound: {mine:?}");
        // The admitted request also left its span tree.
        assert!(has(987_001, "request"), "{mine:?}");
        assert!(has(987_001, "queue"), "{mine:?}");
        assert!(has(987_001, "execute"), "{mine:?}");
        // Sim-domain lines are wall-free and cycle-stamped.
        for l in &mine {
            assert!(!l.contains("t_ms"), "{l}");
            assert!(l.contains("\"cycle\":"), "{l}");
            assert!(
                l.contains("\"src\":\"serve\"") || l.contains("\"src\":\"span\""),
                "{l}"
            );
        }
    }

    #[test]
    fn admitted_requests_emit_well_formed_span_trees() {
        crate::obs::log::init(crate::obs::log::EventLog::in_memory());
        let parent = crate::obs::TraceContext::root("engine-span-test");
        let mut e = Engine::new(EngineOptions {
            cfg: cfg_with_gap(9321),
            inflight: 2,
            ..EngineOptions::default()
        })
        .unwrap();
        // One inherited trace, one self-rooted.
        let mut inherited = submit(988_001, "axpy:832", 4, 0);
        inherited.traceparent = Some(parent.render());
        e.handle(&Request::Submit(inherited));
        e.handle(&Request::Submit(submit(988_002, "axpy:832", 4, 100)));
        let spans: Vec<crate::obs::SpanRecord> = crate::obs::log::recent()
            .iter()
            .filter(|l| l.contains("\"id\":988"))
            .filter_map(|l| crate::obs::SpanRecord::parse(l))
            .collect();
        assert_eq!(spans.len(), 6, "two requests x request/queue/execute");
        let req1 = spans
            .iter()
            .find(|s| s.name == "request" && s.field_u64("id") == Some(988_001))
            .unwrap();
        assert_eq!(req1.trace, parent.trace, "inherited trace id");
        assert_eq!(req1.parent, Some(parent.span));
        let req2 = spans
            .iter()
            .find(|s| s.name == "request" && s.field_u64("id") == Some(988_002))
            .unwrap();
        assert_eq!(req2.parent, None, "no traceparent: self-rooted");
        assert_ne!(req2.trace, req1.trace);
        // The self-rooted trace is a complete, well-formed tree; the
        // inherited one only becomes complete once the client's root
        // span joins it, so check it with the root grafted in.
        let mut all: Vec<crate::obs::SpanRecord> = spans
            .iter()
            .filter(|s| s.trace == req2.trace)
            .cloned()
            .collect();
        crate::obs::span::check_trees(&all).unwrap();
        all = spans.iter().filter(|s| s.trace == req1.trace).cloned().collect();
        let root_line = crate::obs::span::sim_span("client_root", parent, None, 0, u32::MAX as u64)
            .render();
        all.push(crate::obs::SpanRecord::parse(&root_line).unwrap());
        crate::obs::span::check_trees(&all).unwrap();
    }

    #[test]
    fn fast_profile_serves_identical_cycles_and_reports_itself() {
        let cfg = cfg_with_gap(9319);
        let mut reference = Engine::new(EngineOptions {
            cfg: cfg.clone(),
            ..EngineOptions::default()
        })
        .unwrap();
        let mut fast = Engine::new(EngineOptions {
            cfg,
            profile: SimProfile::Fast,
            ..EngineOptions::default()
        })
        .unwrap();
        for i in 0..4 {
            let s = submit(i, "axpy:704", 8, i * 100);
            let (a, b) = (
                reference.handle(&Request::Submit(s.clone())),
                fast.handle(&Request::Submit(s)),
            );
            match (&a, &b) {
                (Reply::Result(r), Reply::Result(f)) => {
                    assert_eq!((r.cycles, r.latency, r.completion), (f.cycles, f.latency, f.completion));
                }
                other => panic!("expected two results, got {other:?}"),
            }
        }
        assert_eq!(reference.stats().profile, "reference");
        assert_eq!(fast.stats().profile, "fast");
        // Separate cache keys: the fast engine simulated for itself
        // rather than borrowing the reference engine's entries.
        assert!(fast.stats().fresh_sims >= 1, "{:?}", fast.stats());
        // The fast daemon's exposition carries the elision counters.
        assert!(fast.prometheus().contains("occamy_sim_events_popped_total"), "{}", fast.prometheus());
        assert!(!reference.prometheus().contains("occamy_sim_"), "{}", reference.prometheus());
    }

    #[test]
    fn drain_retires_everything_and_reports_the_count() {
        let mut e = Engine::new(EngineOptions {
            cfg: cfg_with_gap(9313),
            inflight: 4,
            ..EngineOptions::default()
        })
        .unwrap();
        for i in 0..3 {
            e.handle(&Request::Submit(submit(i, "axpy:512", 4, 0)));
        }
        match e.handle(&Request::Shutdown) {
            Reply::ShuttingDown { drained } => assert_eq!(drained, 3),
            other => panic!("expected shutting-down, got {other:?}"),
        }
    }
}
