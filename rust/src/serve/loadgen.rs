//! Open-loop load generator for the serve daemon.
//!
//! Open-loop means arrivals are drawn from a traffic process and do
//! *not* wait for the system: each submission carries the sampled
//! inter-arrival gap as virtual cycles, and the daemon advances its
//! clock by exactly that gap. The generator itself runs the protocol in
//! lockstep (send, read reply, repeat) — TCP pacing never distorts the
//! schedule because time lives in the requests, not on the wall clock.
//! An overloaded daemon therefore cannot slow arrivals down; it has to
//! shed them, which is precisely the behavior admission control exists
//! to make visible.
//!
//! Four arrival shapes, all seeded and deterministic:
//! - **poisson**: exponential gaps around a mean — memoryless baseline.
//! - **bursty**: on/off. Requests arrive in dense bursts (gaps at a
//!   quarter of the mean) separated by long off-gaps sized so the
//!   long-run rate still matches the mean.
//! - **diurnal**: exponential gaps whose rate swings sinusoidally over
//!   a virtual "day", modeling the daily load curve a shared
//!   simulation service actually sees.
//! - **fixed**: every gap is exactly `mean_gap` — including 0, the
//!   saturating burst `exp/interference` sweeps. The recording path
//!   (`--record`) leans on this: a fixed-gap run reproduces the
//!   interference experiment's schedule on the daemon.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;

use crate::coordinator::Dist;
use crate::obs::span;
use crate::obs::TraceContext;
use crate::offload::RoutineKind;
use crate::rng::Rng64;

use super::proto::{DistSummary, Reply, Request, StatsReply, Submit};

/// The shape of the arrival process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    Poisson,
    Bursty,
    Diurnal,
    Fixed,
}

impl ArrivalKind {
    pub fn parse(s: &str) -> Option<ArrivalKind> {
        match s {
            "poisson" => Some(ArrivalKind::Poisson),
            "bursty" => Some(ArrivalKind::Bursty),
            "diurnal" => Some(ArrivalKind::Diurnal),
            "fixed" => Some(ArrivalKind::Fixed),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::Bursty => "bursty",
            ArrivalKind::Diurnal => "diurnal",
            ArrivalKind::Fixed => "fixed",
        }
    }
}

/// A seeded arrival process: a deterministic stream of inter-arrival
/// gaps in virtual cycles.
#[derive(Debug, Clone)]
pub struct ArrivalProcess {
    kind: ArrivalKind,
    /// Long-run mean inter-arrival gap (cycles).
    mean_gap: f64,
    /// Fixed: the exact gap, unclamped — 0 is the saturating burst.
    fixed: u64,
    /// Bursty: requests per on-burst.
    burst: u64,
    /// Diurnal: virtual cycles per full rate oscillation.
    period: f64,
    rng: Rng64,
    /// Arrivals emitted so far (drives the bursty on/off phase).
    emitted: u64,
    /// Accumulated virtual time (drives the diurnal phase).
    elapsed: f64,
}

impl ArrivalProcess {
    pub fn new(kind: ArrivalKind, mean_gap: u64, burst: u64, period: u64, seed: u64) -> Self {
        Self {
            kind,
            mean_gap: (mean_gap.max(1)) as f64,
            fixed: mean_gap,
            burst: burst.max(2),
            period: (period.max(1)) as f64,
            rng: Rng64::seed_from_u64(seed),
            emitted: 0,
            elapsed: 0.0,
        }
    }

    /// Exponential sample with the given mean (inverse-CDF transform;
    /// `1 - u` keeps `ln` away from zero).
    fn exp(&mut self, mean: f64) -> f64 {
        -(1.0 - self.rng.next_f64()).ln() * mean
    }

    /// The next inter-arrival gap, in virtual cycles.
    pub fn next_gap(&mut self) -> u64 {
        if self.kind == ArrivalKind::Fixed {
            self.emitted += 1;
            self.elapsed += self.fixed as f64;
            return self.fixed;
        }
        let gap = match self.kind {
            ArrivalKind::Fixed => unreachable!("handled above"),
            ArrivalKind::Poisson => self.exp(self.mean_gap),
            ArrivalKind::Bursty => {
                // Every `burst`-th arrival opens a new burst after a
                // long off-gap; within a burst, gaps shrink to a
                // quarter of the mean. Off mass = the other 3/4 of
                // every on-request's budget, spent once per burst.
                if self.emitted % self.burst == 0 {
                    self.exp(0.75 * self.mean_gap * self.burst as f64)
                } else {
                    self.exp(0.25 * self.mean_gap)
                }
            }
            ArrivalKind::Diurnal => {
                // Rate swings ±75% around the mean over one period.
                let phase = (self.elapsed / self.period) * std::f64::consts::TAU;
                let rate_factor = 1.0 + 0.75 * phase.sin();
                self.exp(self.mean_gap / rate_factor.max(0.25))
            }
        };
        self.emitted += 1;
        self.elapsed += gap;
        gap.round() as u64
    }
}

/// Configuration of one load-generator run.
#[derive(Debug, Clone)]
pub struct LoadgenOptions {
    /// Daemon address, e.g. `127.0.0.1:7077`.
    pub addr: String,
    pub requests: u64,
    pub seed: u64,
    pub kind: ArrivalKind,
    /// Long-run mean inter-arrival gap (virtual cycles).
    pub mean_gap: u64,
    /// Bursty: requests per burst.
    pub burst: u64,
    /// Diurnal: cycles per rate oscillation.
    pub period: u64,
    /// Kernel mix, uniform over these campaign-grammar tokens.
    pub mix: Vec<String>,
    /// Forced cluster count (`None` lets the daemon's planner place).
    pub clusters: Option<usize>,
    pub routine: Option<RoutineKind>,
    /// Fetch the daemon's `stats` snapshot after the burst.
    pub fetch_stats: bool,
    /// Fetch the Prometheus text exposition (`metrics` verb) after the
    /// burst and print it verbatim — `occamy loadgen --requests 0
    /// --metrics` is the scrape command.
    pub fetch_metrics: bool,
    /// Send `shutdown` after the burst (and the stats fetch).
    pub shutdown: bool,
    /// Write a client-side span log (JSONL) of send/reply instants on
    /// the virtual arrival timeline: one `client` span per completed
    /// request under one `loadgen` root span. Deterministic under the
    /// seeded arrival process — no wall clocks.
    pub record: Option<PathBuf>,
}

impl Default for LoadgenOptions {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7077".into(),
            requests: 64,
            seed: 1,
            kind: ArrivalKind::Poisson,
            mean_gap: 50_000,
            burst: 8,
            period: 4_000_000,
            mix: vec![
                "axpy:1024".into(),
                "matmul:16".into(),
                "atax:64x64".into(),
                "montecarlo:4096".into(),
            ],
            clusters: None,
            routine: None,
            fetch_stats: true,
            fetch_metrics: false,
            shutdown: false,
            record: None,
        }
    }
}

/// What one load-generator run observed.
#[derive(Debug, Default)]
pub struct LoadgenReport {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    /// Error replies plus protocol failures (short reads, bad replies).
    pub failures: u64,
    pub hits: u64,
    /// End-to-end virtual latency of completed requests.
    pub latency: Dist,
    /// The daemon's snapshot, when `fetch_stats` was set.
    pub stats: Option<StatsReply>,
    /// The Prometheus exposition body, when `fetch_metrics` was set.
    pub metrics: Option<String>,
    /// In-flight jobs the daemon drained, when `shutdown` was set.
    pub drained: Option<u64>,
}

impl LoadgenReport {
    /// Render the run, one grep-stable line per fact. The CI smoke job
    /// matches on `" 0 failure(s)"` and `"0 fresh simulation(s)"`.
    pub fn render(&self) -> String {
        let mut out = format!(
            "loadgen: {} submitted, {} completed, {} rejected, {} failure(s)\n",
            self.submitted, self.completed, self.rejected, self.failures
        );
        if self.latency.count() > 0 {
            // The same reduction the daemon's stats reply uses —
            // client- and server-side percentiles cannot drift apart.
            let s = DistSummary::of(&self.latency);
            out.push_str(&format!(
                "latency p50/p95/p99/max: {}/{}/{}/{} cyc\n",
                s.p50, s.p95, s.p99, s.max
            ));
        }
        if let Some(s) = &self.stats {
            out.push_str(&format!(
                "server: {} hit(s), {} fresh simulation(s), {} SLO violation(s)\n",
                s.hits, s.fresh_sims, s.slo_violations
            ));
        }
        if let Some(d) = self.drained {
            out.push_str(&format!("shutdown: server drained {d} in-flight job(s)\n"));
        }
        if let Some(m) = &self.metrics {
            // Verbatim, last: `loadgen --requests 0 --metrics` pipes
            // straight into a scrape file.
            out.push_str(m);
        }
        out
    }
}

/// One lockstep exchange: write the request line, read one reply line.
fn exchange(
    writer: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    req: &Request,
) -> anyhow::Result<Reply> {
    writer.write_all(format!("{}\n", req.to_line()).as_bytes())?;
    writer.flush()?;
    let mut line = String::new();
    let n = reader.read_line(&mut line)?;
    anyhow::ensure!(n > 0, "server closed the connection mid-exchange");
    Reply::from_line(line.trim()).map_err(|e| anyhow::anyhow!("bad reply: {e}"))
}

/// Run one seeded open-loop burst against a serve daemon.
pub fn run(opts: &LoadgenOptions) -> anyhow::Result<LoadgenReport> {
    anyhow::ensure!(!opts.mix.is_empty(), "loadgen needs a non-empty kernel mix");
    let stream =
        TcpStream::connect(&opts.addr).map_err(|e| anyhow::anyhow!("connect {}: {e}", opts.addr))?;
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);

    let mut arrivals =
        ArrivalProcess::new(opts.kind, opts.mean_gap, opts.burst, opts.period, opts.seed);
    // Independent stream for the kernel mix so changing the arrival
    // shape never reshuffles which kernels get submitted.
    let mut mix_rng = Rng64::seed_from_u64(opts.seed ^ 0x6D69_785F_7365_6564);
    let mut report = LoadgenReport::default();

    // Every request carries a trace context derived from the seed, so
    // the daemon's request spans stitch under this run's root span.
    let root = TraceContext::root(&format!("loadgen-{}", opts.seed));
    let mut record_lines: Vec<String> = Vec::new();
    // The client's virtual send clock mirrors the daemon's arrival
    // clock exactly: both advance by the same per-request gaps.
    let mut send_clock: u64 = 0;
    let mut last_end: u64 = 0;

    for id in 0..opts.requests {
        let kernel = opts.mix[mix_rng.gen_range_usize(0, opts.mix.len())].clone();
        let gap = arrivals.next_gap();
        send_clock = send_clock.saturating_add(gap);
        let ctx = root.child(&kernel, id);
        let submit = Submit {
            id,
            kernel,
            clusters: opts.clusters,
            routine: opts.routine,
            gap: Some(gap),
            seed: Some(opts.seed.wrapping_add(id)),
            traceparent: Some(ctx.render()),
        };
        report.submitted += 1;
        match exchange(&mut writer, &mut reader, &Request::Submit(submit))? {
            Reply::Result(r) => {
                report.completed += 1;
                report.latency.record(r.latency);
                if r.hit {
                    report.hits += 1;
                }
                if opts.record.is_some() {
                    // Send instant and client-observed latency, both on
                    // the virtual timeline: the client span encloses the
                    // daemon's request span byte-deterministically.
                    record_lines.push(
                        span::sim_span("client", ctx, Some(root.span), send_clock, r.latency)
                            .u64("id", id)
                            .str("kernel", &r.kernel)
                            .render(),
                    );
                    last_end = last_end.max(send_clock.saturating_add(r.latency));
                }
            }
            Reply::Rejected(_) => report.rejected += 1,
            Reply::Error(_) => report.failures += 1,
            other => {
                report.failures += 1;
                eprintln!("loadgen: unexpected reply to submit: {other:?}");
            }
        }
    }

    if let Some(path) = &opts.record {
        let mut out = String::new();
        // Root span first, spanning the whole recorded run, so the file
        // alone forms a complete tree.
        out.push_str(&span::sim_span("loadgen", root, None, 0, last_end).render());
        out.push('\n');
        for l in &record_lines {
            out.push_str(l);
            out.push('\n');
        }
        std::fs::write(path, out)
            .map_err(|e| anyhow::anyhow!("write record {}: {e}", path.display()))?;
    }

    if opts.fetch_stats {
        match exchange(&mut writer, &mut reader, &Request::Stats)? {
            Reply::Stats(s) => report.stats = Some(s),
            other => {
                report.failures += 1;
                eprintln!("loadgen: unexpected reply to stats: {other:?}");
            }
        }
    }
    if opts.fetch_metrics {
        match exchange(&mut writer, &mut reader, &Request::Metrics)? {
            Reply::Metrics(m) => report.metrics = Some(m.text),
            other => {
                report.failures += 1;
                eprintln!("loadgen: unexpected reply to metrics: {other:?}");
            }
        }
    }
    if opts.shutdown {
        match exchange(&mut writer, &mut reader, &Request::Shutdown)? {
            Reply::ShuttingDown { drained } => report.drained = Some(drained),
            other => {
                report.failures += 1;
                eprintln!("loadgen: unexpected reply to shutdown: {other:?}");
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gaps(kind: ArrivalKind, seed: u64, n: usize) -> Vec<u64> {
        let mut p = ArrivalProcess::new(kind, 10_000, 8, 1_000_000, seed);
        (0..n).map(|_| p.next_gap()).collect()
    }

    #[test]
    fn same_seed_same_gaps() {
        for kind in [ArrivalKind::Poisson, ArrivalKind::Bursty, ArrivalKind::Diurnal] {
            assert_eq!(gaps(kind, 42, 256), gaps(kind, 42, 256), "{kind:?}");
            assert_ne!(gaps(kind, 42, 256), gaps(kind, 43, 256), "{kind:?}");
        }
    }

    #[test]
    fn poisson_mean_tracks_the_target() {
        let g = gaps(ArrivalKind::Poisson, 7, 20_000);
        let mean = g.iter().sum::<u64>() as f64 / g.len() as f64;
        assert!(
            (mean - 10_000.0).abs() < 500.0,
            "empirical mean {mean} strays from the 10k target"
        );
    }

    #[test]
    fn bursty_alternates_dense_and_sparse() {
        // Within a burst the gaps average a quarter of the mean; the
        // burst-opening off-gaps are an order of magnitude longer. The
        // long-run rate still matches the configured mean.
        let g = gaps(ArrivalKind::Bursty, 11, 16_000);
        let (mut on_sum, mut on_n, mut off_sum, mut off_n) = (0u64, 0u64, 0u64, 0u64);
        for (i, gap) in g.iter().enumerate() {
            if i as u64 % 8 == 0 {
                off_sum += gap;
                off_n += 1;
            } else {
                on_sum += gap;
                on_n += 1;
            }
        }
        let on_mean = on_sum as f64 / on_n as f64;
        let off_mean = off_sum as f64 / off_n as f64;
        assert!(on_mean < 3_000.0, "on-burst gaps are dense: {on_mean}");
        assert!(off_mean > 50_000.0, "off gaps are sparse: {off_mean}");
        let overall = g.iter().sum::<u64>() as f64 / g.len() as f64;
        assert!(
            (overall - 10_000.0).abs() < 1_000.0,
            "long-run mean {overall} strays from the 10k target"
        );
    }

    #[test]
    fn diurnal_rate_actually_swings() {
        // Bucket arrivals by phase of the virtual day: the peak half
        // of the cycle must see meaningfully more arrivals than the
        // trough half.
        let mut p = ArrivalProcess::new(ArrivalKind::Diurnal, 10_000, 8, 1_000_000, 13);
        let mut t = 0.0f64;
        let (mut peak, mut trough) = (0u64, 0u64);
        for _ in 0..50_000 {
            t += p.next_gap() as f64;
            let phase = (t / 1_000_000.0).fract();
            if phase < 0.5 {
                peak += 1;
            } else {
                trough += 1;
            }
        }
        assert!(
            peak as f64 > trough as f64 * 1.5,
            "peak {peak} vs trough {trough}: no diurnal swing"
        );
    }

    #[test]
    fn arrival_kind_names_round_trip() {
        for kind in [ArrivalKind::Poisson, ArrivalKind::Bursty, ArrivalKind::Diurnal] {
            assert_eq!(ArrivalKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(ArrivalKind::parse("sawtooth"), None);
    }

    #[test]
    fn report_renders_the_grep_targets() {
        let mut r = LoadgenReport {
            submitted: 4,
            completed: 4,
            ..LoadgenReport::default()
        };
        for v in [100, 200, 300, 400] {
            r.latency.record(v);
        }
        r.stats = Some(StatsReply {
            hits: 4,
            ..sample_empty_stats()
        });
        r.drained = Some(0);
        r.metrics = Some("occamy_serve_requests_total{outcome=\"completed\"} 4\n".into());
        let text = r.render();
        assert!(text.contains("4 submitted, 4 completed, 0 rejected, 0 failure(s)"), "{text}");
        assert!(text.contains("0 fresh simulation(s)"), "{text}");
        assert!(text.contains("drained 0 in-flight job(s)"), "{text}");
        // Client- and server-side percentiles share DistSummary::of.
        let s = DistSummary::of(&r.latency);
        assert!(
            text.contains(&format!("latency p50/p95/p99/max: {}/{}/{}/{} cyc", s.p50, s.p95, s.p99, s.max)),
            "{text}"
        );
        assert!(text.ends_with("occamy_serve_requests_total{outcome=\"completed\"} 4\n"), "{text}");
    }

    fn sample_empty_stats() -> StatsReply {
        StatsReply {
            completed: 0,
            rejected: 0,
            errors: 0,
            host_placements: 0,
            accel_placements: 0,
            hits: 0,
            fresh_sims: 0,
            queue: Default::default(),
            service: Default::default(),
            latency: Default::default(),
            slo_cycles: 1_000_000,
            slo_violations: 0,
            jobs_per_sim_second: None,
            profile: "reference".to_string(),
        }
    }

    #[test]
    fn fixed_gaps_are_raw_and_constant() {
        // No clamp, no rng: gap 0 stays 0 — the saturating burst the
        // interference sweep uses — and any other value repeats exactly.
        let mut zero = ArrivalProcess::new(ArrivalKind::Fixed, 0, 8, 1_000_000, 42);
        let mut paced = ArrivalProcess::new(ArrivalKind::Fixed, 777, 8, 1_000_000, 42);
        for _ in 0..64 {
            assert_eq!(zero.next_gap(), 0);
            assert_eq!(paced.next_gap(), 777);
        }
    }

    #[test]
    fn arrival_kind_fixed_round_trips() {
        assert_eq!(ArrivalKind::parse("fixed"), Some(ArrivalKind::Fixed));
        assert_eq!(ArrivalKind::Fixed.name(), "fixed");
    }
}
