//! Simulation-as-a-service: a long-lived daemon in front of the DES.
//!
//! The batch coordinator answers "run these N jobs"; this module answers
//! "keep answering jobs" — the shape a shared simulation service on a
//! login node actually has. Five pieces:
//!
//! * [`proto`] — line-delimited JSON wire protocol (submit / stats /
//!   metrics / ping / shutdown), deterministic bytes, malformed input
//!   downgraded to per-request errors.
//! * [`engine`] — the deterministic core: open-loop arrival clock,
//!   admission control with a bounded queue (`rejected: overloaded`
//!   instead of unbounded delay), scheduling through the coordinator's
//!   [`OccupancyModel`](crate::coordinator::OccupancyModel), and
//!   three-tier memoization (process cache → campaign
//!   [`TraceStore`](crate::campaign::TraceStore) → fresh simulation).
//! * [`metrics`] — per-request queue/service/latency distributions,
//!   hit/miss counters, SLO accounting, the `stats` snapshot and the
//!   periodic summary line. The same counters register into an
//!   [`obs::metrics`](crate::obs::metrics) registry, answered in
//!   Prometheus text form by the `metrics` wire verb (scrape with
//!   `occamy loadgen --requests 0 --metrics`). With `--log FILE` (or
//!   the spec's `log` key) the engine also emits a structured JSONL
//!   event per request-lifecycle step through
//!   [`obs::log`](crate::obs::log) — accept, memoization tier,
//!   dispatch, complete, reject — stamped in virtual cycles.
//! * [`server`] — the TCP front end: concurrent sessions, graceful
//!   drain on shutdown, nothing a client writes can take it down.
//! * [`loadgen`] — a seeded open-loop client: Poisson, bursty, diurnal
//!   and fixed arrivals over a kernel mix, reporting client-side
//!   latency percentiles next to the server's own stats. Every submit
//!   carries a deterministic `traceparent`, so the daemon's
//!   request/queue/execute spans ([`obs::span`](crate::obs::span))
//!   stitch under the client's trace; `--record FILE` writes the
//!   client-side span log on the same virtual clock.
//!
//! Because time is virtual and arrivals ride in the requests, a serve
//! run is a *reproducible experiment*: the same seed and mix produce the
//! same schedule, latencies and rejections on any machine, warm or cold.
//! `occamy serve --listen` starts the daemon, `occamy loadgen` drives
//! it, `occamy serve --oneshot` keeps the original in-process batch
//! path, and `occamy bench serve` measures the engine's service rate.

pub mod engine;
pub mod loadgen;
pub mod metrics;
pub mod proto;
pub mod server;
pub mod spec;

pub use engine::{Engine, EngineOptions};
pub use loadgen::{ArrivalKind, ArrivalProcess, LoadgenOptions, LoadgenReport};
pub use metrics::ServeMetrics;
pub use proto::{DistSummary, MetricsReply, Reply, Request, StatsReply, Submit};
pub use server::Server;
pub use spec::ServeSpec;
