//! Per-request metrics for the serve daemon.
//!
//! Everything is measured in *virtual* cycles on the daemon's open-loop
//! timeline: queue delay (arrival → dispatch), service (the isolated DES
//! runtime), and end-to-end latency (their sum), each kept as a full
//! sample distribution so the `stats` verb can answer p50/p95/p99
//! honestly rather than from a lossy sketch. Latency is additionally
//! judged against a configurable SLO so a load-generator run summarizes
//! to one number: how many requests the fabric served late.
//!
//! Hit/miss bookkeeping counts *fresh simulations* as misses — the
//! number the memoization proof greps for. Memory and disk hits are kept
//! separately so a warm-store rerun is distinguishable from same-process
//! caching.

use crate::campaign::stream::Source;
use crate::coordinator::Dist;
use crate::obs::metrics::{Registry, CYCLE_BUCKETS};

use super::proto::{DistSummary, StatsReply};

/// Counters and distributions for one daemon lifetime.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    pub completed: u64,
    pub rejected: u64,
    pub errors: u64,
    pub host_placements: u64,
    pub accel_placements: u64,
    pub mem_hits: u64,
    pub disk_hits: u64,
    /// Fresh simulations (request-level misses).
    pub fresh_sims: u64,
    pub slo_cycles: u64,
    pub slo_violations: u64,
    pub queue: Dist,
    pub service: Dist,
    pub latency: Dist,
}

impl ServeMetrics {
    pub fn new(slo_cycles: u64) -> Self {
        Self {
            slo_cycles,
            ..Self::default()
        }
    }

    /// Record one accelerator-placed completion.
    pub fn record_accel(&mut self, service: u64, queue_delay: u64, source: Source) {
        self.completed += 1;
        self.accel_placements += 1;
        match source {
            Source::Mem => self.mem_hits += 1,
            Source::Disk => self.disk_hits += 1,
            Source::Sim => self.fresh_sims += 1,
        }
        let latency = service + queue_delay;
        self.queue.record(queue_delay);
        self.service.record(service);
        self.latency.record(latency);
        if latency > self.slo_cycles {
            self.slo_violations += 1;
        }
    }

    /// Record one host-placed completion (no simulation, no queueing —
    /// the host core runs it outside the fabric's dispatch window).
    pub fn record_host(&mut self, cycles: u64) {
        self.completed += 1;
        self.host_placements += 1;
        self.queue.record(0);
        self.service.record(cycles);
        self.latency.record(cycles);
        if cycles > self.slo_cycles {
            self.slo_violations += 1;
        }
    }

    pub fn record_rejection(&mut self) {
        self.rejected += 1;
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    /// Memoization hits (memory + disk).
    pub fn hits(&self) -> u64 {
        self.mem_hits + self.disk_hits
    }

    /// Simulated-time throughput, mirroring
    /// `coordinator::Metrics::jobs_per_sim_second` but already mapped to
    /// `None` where the f64 would be non-finite — the wire encodes that
    /// as `null`.
    pub fn jobs_per_sim_second(&self) -> Option<f64> {
        if self.completed == 0 || self.latency.sum() == 0 {
            return None;
        }
        Some(self.completed as f64 / (self.latency.sum() as f64 / 1e9))
    }

    /// The `stats` reply for the current state. Percentiles reduce
    /// through [`DistSummary::of`], the same math the load generator
    /// and serve bench report with.
    pub fn snapshot(&self) -> StatsReply {
        StatsReply {
            completed: self.completed,
            rejected: self.rejected,
            errors: self.errors,
            host_placements: self.host_placements,
            accel_placements: self.accel_placements,
            hits: self.hits(),
            fresh_sims: self.fresh_sims,
            queue: DistSummary::of(&self.queue),
            service: DistSummary::of(&self.service),
            latency: DistSummary::of(&self.latency),
            slo_cycles: self.slo_cycles,
            slo_violations: self.slo_violations,
            jobs_per_sim_second: self.jobs_per_sim_second(),
            // The engine overwrites this with its actual profile; bare
            // snapshots (tests, summaries) report the default.
            profile: "reference".to_string(),
        }
    }

    /// Register every counter and distribution into a Prometheus
    /// registry — the body of the `metrics` wire verb. Covers the full
    /// `stats` surface: request outcomes, placements, memoization
    /// tiers, SLO accounting, throughput, and the three cycle
    /// distributions as histograms.
    pub fn register(&self, r: &mut Registry) {
        let outcomes = "Requests by outcome (completed, rejected, error)";
        r.counter("occamy_serve_requests_total", outcomes, &[("outcome", "completed")], self.completed);
        r.counter("occamy_serve_requests_total", outcomes, &[("outcome", "rejected")], self.rejected);
        r.counter("occamy_serve_requests_total", outcomes, &[("outcome", "error")], self.errors);
        let placements = "Completed jobs by placement";
        r.counter("occamy_serve_placements_total", placements, &[("placement", "host")], self.host_placements);
        r.counter("occamy_serve_placements_total", placements, &[("placement", "accel")], self.accel_placements);
        let tiers = "Accelerator jobs by memoization tier (mem/disk hits, fresh sims)";
        r.counter("occamy_serve_store_requests_total", tiers, &[("tier", "mem")], self.mem_hits);
        r.counter("occamy_serve_store_requests_total", tiers, &[("tier", "disk")], self.disk_hits);
        r.counter("occamy_serve_store_requests_total", tiers, &[("tier", "sim")], self.fresh_sims);
        r.counter(
            "occamy_serve_slo_violations_total",
            "Completed jobs whose end-to-end latency exceeded the SLO",
            &[],
            self.slo_violations,
        );
        r.gauge(
            "occamy_serve_slo_cycles",
            "The latency SLO in virtual cycles",
            &[],
            self.slo_cycles as f64,
        );
        if let Some(rate) = self.jobs_per_sim_second() {
            r.gauge(
                "occamy_serve_jobs_per_sim_second",
                "Simulated-time throughput (jobs per simulated second)",
                &[],
                rate,
            );
        }
        r.histogram(
            "occamy_serve_queue_cycles",
            "Queueing delay per job, virtual cycles (arrival to dispatch)",
            &self.queue,
            &CYCLE_BUCKETS,
        );
        r.histogram(
            "occamy_serve_service_cycles",
            "Isolated service time per job, virtual cycles",
            &self.service,
            &CYCLE_BUCKETS,
        );
        r.histogram(
            "occamy_serve_latency_cycles",
            "End-to-end latency per job, virtual cycles (service + queueing)",
            &self.latency,
            &CYCLE_BUCKETS,
        );
    }

    /// The periodic one-line summary the daemon prints.
    pub fn summary_line(&self) -> String {
        let s = self.snapshot();
        format!(
            "serve: {} done ({} rejected, {} error(s)), {} hit(s), {} fresh simulation(s), \
             latency p50/p95/p99 {}/{}/{} cyc, {} over the {}-cyc SLO",
            s.completed,
            s.rejected,
            s.errors,
            s.hits,
            s.fresh_sims,
            s.latency.p50,
            s.latency.p95,
            s.latency.p99,
            s.slo_violations,
            s.slo_cycles
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::json::Json;

    #[test]
    fn percentiles_track_recorded_latencies() {
        let mut m = ServeMetrics::new(1_000);
        for v in 1..=100u64 {
            m.record_accel(v * 10, 0, Source::Sim);
        }
        let s = m.snapshot();
        assert_eq!(s.completed, 100);
        assert_eq!(s.fresh_sims, 100);
        assert_eq!(s.hits, 0);
        assert_eq!(s.latency.count, 100);
        // Nearest-rank over [10, 20, .., 1000]: rank round(99 * .5) = 50.
        assert_eq!(s.latency.p50, 510);
        assert_eq!(s.latency.p99, 990);
        assert_eq!(s.latency.max, 1000);
        // No latency exceeds the 1000-cycle SLO (1000 is on time).
        assert_eq!(s.slo_violations, 0);
        let mut m = ServeMetrics::new(500);
        for v in 1..=10u64 {
            m.record_accel(v * 100, 0, Source::Mem);
        }
        assert_eq!(m.snapshot().slo_violations, 5, "600..=1000 are late");
    }

    #[test]
    fn hit_miss_split_by_source() {
        let mut m = ServeMetrics::new(u64::MAX);
        m.record_accel(100, 0, Source::Sim);
        m.record_accel(100, 10, Source::Disk);
        m.record_accel(100, 20, Source::Mem);
        m.record_host(40);
        m.record_rejection();
        m.record_error();
        let s = m.snapshot();
        assert_eq!((s.completed, s.rejected, s.errors), (4, 1, 1));
        assert_eq!((s.hits, s.fresh_sims), (2, 1));
        assert_eq!((s.host_placements, s.accel_placements), (1, 3));
        assert_eq!(s.queue.max, 20);
    }

    #[test]
    fn degenerate_throughput_is_null_on_the_wire() {
        // Zero-cycle completions: the coordinator's f64 API says
        // INFINITY; the serve snapshot says None and the serialized
        // stats reply stays valid JSON with a null rate.
        let mut m = ServeMetrics::new(1_000);
        m.record_host(0);
        assert_eq!(m.jobs_per_sim_second(), None);
        let line = crate::serve::proto::Reply::Stats(m.snapshot()).to_line();
        assert!(line.contains("\"jobs_per_sim_second\":null"), "{line}");
        assert!(Json::parse(&line).is_ok(), "{line}");
        // And an empty daemon reports zeros, not NaN percentiles.
        let empty = ServeMetrics::new(1_000).snapshot();
        assert_eq!(empty.latency, DistSummary::default());
        assert_eq!(empty.jobs_per_sim_second, None);
    }

    #[test]
    fn register_covers_every_stats_counter() {
        let mut m = ServeMetrics::new(1_000);
        m.record_accel(2_000, 100, Source::Sim);
        m.record_accel(500, 0, Source::Mem);
        m.record_accel(500, 0, Source::Disk);
        m.record_host(40);
        m.record_rejection();
        m.record_error();
        let mut r = Registry::new();
        m.register(&mut r);
        let text = r.render();
        for needle in [
            "occamy_serve_requests_total{outcome=\"completed\"} 4\n",
            "occamy_serve_requests_total{outcome=\"rejected\"} 1\n",
            "occamy_serve_requests_total{outcome=\"error\"} 1\n",
            "occamy_serve_placements_total{placement=\"host\"} 1\n",
            "occamy_serve_placements_total{placement=\"accel\"} 3\n",
            "occamy_serve_store_requests_total{tier=\"mem\"} 1\n",
            "occamy_serve_store_requests_total{tier=\"disk\"} 1\n",
            "occamy_serve_store_requests_total{tier=\"sim\"} 1\n",
            "occamy_serve_slo_violations_total 1\n",
            "occamy_serve_slo_cycles 1000\n",
            "# TYPE occamy_serve_jobs_per_sim_second gauge\n",
            "occamy_serve_queue_cycles_bucket{le=\"1000\"} 4\n",
            "occamy_serve_service_cycles_count 4\n",
            "occamy_serve_latency_cycles_sum 3140\n",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // An idle daemon renders too (no NaN gauges): the throughput
        // gauge is simply absent until it is meaningful.
        let mut r = Registry::new();
        ServeMetrics::new(1_000).register(&mut r);
        let idle = r.render();
        assert!(!idle.contains("occamy_serve_jobs_per_sim_second"), "{idle}");
        assert!(idle.contains("occamy_serve_requests_total{outcome=\"completed\"} 0\n"), "{idle}");
    }

    #[test]
    fn summary_line_carries_the_grep_targets() {
        let mut m = ServeMetrics::new(1_000_000);
        m.record_accel(500, 0, Source::Disk);
        let line = m.summary_line();
        assert!(line.contains("1 done"), "{line}");
        assert!(line.contains("1 hit(s)"), "{line}");
        assert!(line.contains("0 fresh simulation(s)"), "{line}");
    }
}
