//! Per-request metrics for the serve daemon.
//!
//! Everything is measured in *virtual* cycles on the daemon's open-loop
//! timeline: queue delay (arrival → dispatch), service (the isolated DES
//! runtime), and end-to-end latency (their sum), each kept as a full
//! sample distribution so the `stats` verb can answer p50/p95/p99
//! honestly rather than from a lossy sketch. Latency is additionally
//! judged against a configurable SLO so a load-generator run summarizes
//! to one number: how many requests the fabric served late.
//!
//! Hit/miss bookkeeping counts *fresh simulations* as misses — the
//! number the memoization proof greps for. Memory and disk hits are kept
//! separately so a warm-store rerun is distinguishable from same-process
//! caching.

use crate::campaign::stream::Source;
use crate::coordinator::Dist;

use super::proto::{DistSummary, StatsReply};

/// Counters and distributions for one daemon lifetime.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    pub completed: u64,
    pub rejected: u64,
    pub errors: u64,
    pub host_placements: u64,
    pub accel_placements: u64,
    pub mem_hits: u64,
    pub disk_hits: u64,
    /// Fresh simulations (request-level misses).
    pub fresh_sims: u64,
    pub slo_cycles: u64,
    pub slo_violations: u64,
    pub queue: Dist,
    pub service: Dist,
    pub latency: Dist,
}

impl ServeMetrics {
    pub fn new(slo_cycles: u64) -> Self {
        Self {
            slo_cycles,
            ..Self::default()
        }
    }

    /// Record one accelerator-placed completion.
    pub fn record_accel(&mut self, service: u64, queue_delay: u64, source: Source) {
        self.completed += 1;
        self.accel_placements += 1;
        match source {
            Source::Mem => self.mem_hits += 1,
            Source::Disk => self.disk_hits += 1,
            Source::Sim => self.fresh_sims += 1,
        }
        let latency = service + queue_delay;
        self.queue.record(queue_delay);
        self.service.record(service);
        self.latency.record(latency);
        if latency > self.slo_cycles {
            self.slo_violations += 1;
        }
    }

    /// Record one host-placed completion (no simulation, no queueing —
    /// the host core runs it outside the fabric's dispatch window).
    pub fn record_host(&mut self, cycles: u64) {
        self.completed += 1;
        self.host_placements += 1;
        self.queue.record(0);
        self.service.record(cycles);
        self.latency.record(cycles);
        if cycles > self.slo_cycles {
            self.slo_violations += 1;
        }
    }

    pub fn record_rejection(&mut self) {
        self.rejected += 1;
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    /// Memoization hits (memory + disk).
    pub fn hits(&self) -> u64 {
        self.mem_hits + self.disk_hits
    }

    /// Simulated-time throughput, mirroring
    /// `coordinator::Metrics::jobs_per_sim_second` but already mapped to
    /// `None` where the f64 would be non-finite — the wire encodes that
    /// as `null`.
    pub fn jobs_per_sim_second(&self) -> Option<f64> {
        if self.completed == 0 || self.latency.sum() == 0 {
            return None;
        }
        Some(self.completed as f64 / (self.latency.sum() as f64 / 1e9))
    }

    fn summarize(d: &Dist) -> DistSummary {
        if d.count() == 0 {
            return DistSummary::default();
        }
        let q = d.quantiles(&[0.50, 0.95, 0.99]);
        DistSummary {
            count: d.count() as u64,
            p50: q[0],
            p95: q[1],
            p99: q[2],
            max: d.max(),
        }
    }

    /// The `stats` reply for the current state.
    pub fn snapshot(&self) -> StatsReply {
        StatsReply {
            completed: self.completed,
            rejected: self.rejected,
            errors: self.errors,
            host_placements: self.host_placements,
            accel_placements: self.accel_placements,
            hits: self.hits(),
            fresh_sims: self.fresh_sims,
            queue: Self::summarize(&self.queue),
            service: Self::summarize(&self.service),
            latency: Self::summarize(&self.latency),
            slo_cycles: self.slo_cycles,
            slo_violations: self.slo_violations,
            jobs_per_sim_second: self.jobs_per_sim_second(),
        }
    }

    /// The periodic one-line summary the daemon prints.
    pub fn summary_line(&self) -> String {
        let s = self.snapshot();
        format!(
            "serve: {} done ({} rejected, {} error(s)), {} hit(s), {} fresh simulation(s), \
             latency p50/p95/p99 {}/{}/{} cyc, {} over the {}-cyc SLO",
            s.completed,
            s.rejected,
            s.errors,
            s.hits,
            s.fresh_sims,
            s.latency.p50,
            s.latency.p95,
            s.latency.p99,
            s.slo_violations,
            s.slo_cycles
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::json::Json;

    #[test]
    fn percentiles_track_recorded_latencies() {
        let mut m = ServeMetrics::new(1_000);
        for v in 1..=100u64 {
            m.record_accel(v * 10, 0, Source::Sim);
        }
        let s = m.snapshot();
        assert_eq!(s.completed, 100);
        assert_eq!(s.fresh_sims, 100);
        assert_eq!(s.hits, 0);
        assert_eq!(s.latency.count, 100);
        // Nearest-rank over [10, 20, .., 1000]: rank round(99 * .5) = 50.
        assert_eq!(s.latency.p50, 510);
        assert_eq!(s.latency.p99, 990);
        assert_eq!(s.latency.max, 1000);
        // No latency exceeds the 1000-cycle SLO (1000 is on time).
        assert_eq!(s.slo_violations, 0);
        let mut m = ServeMetrics::new(500);
        for v in 1..=10u64 {
            m.record_accel(v * 100, 0, Source::Mem);
        }
        assert_eq!(m.snapshot().slo_violations, 5, "600..=1000 are late");
    }

    #[test]
    fn hit_miss_split_by_source() {
        let mut m = ServeMetrics::new(u64::MAX);
        m.record_accel(100, 0, Source::Sim);
        m.record_accel(100, 10, Source::Disk);
        m.record_accel(100, 20, Source::Mem);
        m.record_host(40);
        m.record_rejection();
        m.record_error();
        let s = m.snapshot();
        assert_eq!((s.completed, s.rejected, s.errors), (4, 1, 1));
        assert_eq!((s.hits, s.fresh_sims), (2, 1));
        assert_eq!((s.host_placements, s.accel_placements), (1, 3));
        assert_eq!(s.queue.max, 20);
    }

    #[test]
    fn degenerate_throughput_is_null_on_the_wire() {
        // Zero-cycle completions: the coordinator's f64 API says
        // INFINITY; the serve snapshot says None and the serialized
        // stats reply stays valid JSON with a null rate.
        let mut m = ServeMetrics::new(1_000);
        m.record_host(0);
        assert_eq!(m.jobs_per_sim_second(), None);
        let line = crate::serve::proto::Reply::Stats(m.snapshot()).to_line();
        assert!(line.contains("\"jobs_per_sim_second\":null"), "{line}");
        assert!(Json::parse(&line).is_ok(), "{line}");
        // And an empty daemon reports zeros, not NaN percentiles.
        let empty = ServeMetrics::new(1_000).snapshot();
        assert_eq!(empty.latency, DistSummary::default());
        assert_eq!(empty.jobs_per_sim_second, None);
    }

    #[test]
    fn summary_line_carries_the_grep_targets() {
        let mut m = ServeMetrics::new(1_000_000);
        m.record_accel(500, 0, Source::Disk);
        let line = m.summary_line();
        assert!(line.contains("1 done"), "{line}");
        assert!(line.contains("1 hit(s)"), "{line}");
        assert!(line.contains("0 fresh simulation(s)"), "{line}");
    }
}
