//! The serve daemon's wire protocol: line-delimited JSON over TCP.
//!
//! One request per line, one reply line per request, in order — a
//! session is a lockstep request/reply stream, which keeps the protocol
//! trivially framable (no length prefixes, no multiplexing) and makes
//! client-side accounting deterministic. Every message is a single JSON
//! object; requests carry an `"op"` discriminant, replies a `"reply"`
//! discriminant. Encoding rides on [`runtime::json`](crate::runtime::json)
//! — deterministic key order, integers without fractional suffixes, and
//! non-finite floats as `null` — so replies are stable byte-for-byte for
//! a given state, and a `stats` reply can never emit unparseable JSON
//! no matter how degenerate a metric gets.
//!
//! Unknown operations, malformed JSON, and semantically invalid requests
//! (bad kernel token, out-of-range cluster count) are all *per-request*
//! failures: the daemon answers with an `error` reply and keeps the
//! session open. Nothing a client writes can take the daemon down.

use std::collections::BTreeMap;

use crate::campaign::stream::Source;
use crate::coordinator::{Dist, Placement};
use crate::offload::RoutineKind;
use crate::runtime::json::Json;

/// A client → daemon message.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Submit one job (the `JobRequest` shape: id, kernel, clusters,
    /// routine, seed) plus the open-loop arrival gap.
    Submit(Submit),
    /// Ask for the daemon's metrics snapshot.
    Stats,
    /// Ask for the same counters in Prometheus text exposition format
    /// (`obs::metrics`), for scrape pipelines; `stats` stays the JSON
    /// form.
    Metrics,
    /// Liveness probe.
    Ping,
    /// Graceful shutdown: drain the virtual timeline, stop accepting.
    Shutdown,
}

/// One job submission.
#[derive(Debug, Clone, PartialEq)]
pub struct Submit {
    /// Client-chosen id, echoed on the reply.
    pub id: u64,
    /// Kernel token in the campaign grammar (`axpy:1024`, `matmul:32`,
    /// `montecarlo:4096`, ...).
    pub kernel: String,
    /// Forced cluster count; `None` lets the planner decide (which may
    /// place the job on the host).
    pub clusters: Option<usize>,
    /// Offload routine; `None` means multicast (the optimized default).
    pub routine: Option<RoutineKind>,
    /// Virtual cycles since the previous arrival on the daemon's
    /// open-loop clock; `None` uses the daemon's configured default.
    pub gap: Option<u64>,
    /// Reserved for numerics-bearing backends; the timing-only daemon
    /// accepts and ignores it (kept so submissions stay
    /// `JobRequest`-shaped).
    pub seed: Option<u64>,
    /// Inherited trace context, `<trace:016x>-<span:016x>`
    /// ([`crate::obs::TraceContext`]). When present the daemon parents
    /// this request's span under it; when absent the request gets a
    /// self-rooted trace. Unparseable values are ignored, not errors —
    /// tracing never fails a submission.
    pub traceparent: Option<String>,
}

/// A daemon → client message.
#[derive(Debug, Clone, PartialEq)]
pub enum Reply {
    /// A completed job's schedule on the virtual timeline.
    Result(JobReply),
    /// Admission control refused the job: the bounded queue is full.
    Rejected(Rejected),
    /// The request could not be processed; the session stays open.
    Error(ErrorReply),
    /// Answer to `ping`.
    Pong,
    /// Answer to `stats`.
    Stats(StatsReply),
    /// Answer to `metrics`: the Prometheus text exposition body.
    Metrics(MetricsReply),
    /// Answer to `shutdown`: the daemon drained `drained` in-flight jobs
    /// off the virtual timeline and is closing.
    ShuttingDown { drained: u64 },
}

/// The virtual-time outcome of one admitted job.
#[derive(Debug, Clone, PartialEq)]
pub struct JobReply {
    pub id: u64,
    /// Echo of the submitted kernel token.
    pub kernel: String,
    pub placement: Placement,
    pub routine: RoutineKind,
    /// Isolated service cycles (bit-identical to the serial coordinator
    /// — contention never changes a job's own DES runtime).
    pub cycles: u64,
    /// Wait from open-loop arrival to dispatch (window + slots +
    /// clusters). Zero for host placements.
    pub queue_delay: u64,
    /// `cycles + queue_delay`.
    pub latency: u64,
    /// Dispatch instant on the virtual timeline.
    pub start: u64,
    /// `start + cycles`.
    pub completion: u64,
    /// Which memoization layer served the trace (`None` for host
    /// placements — they never simulate).
    pub source: Option<Source>,
    /// `true` when the trace came from memory or disk, not a fresh
    /// simulation.
    pub hit: bool,
}

/// An `overloaded` admission rejection.
#[derive(Debug, Clone, PartialEq)]
pub struct Rejected {
    pub id: u64,
    /// Always `"overloaded"` today; a field so future admission policies
    /// can reject for other reasons without a wire break.
    pub reason: String,
    /// Jobs outstanding on the virtual timeline at the arrival instant.
    pub backlog: u64,
    /// The admission bound (`inflight * queue_factor`).
    pub bound: u64,
}

/// A per-request failure. `id` is present when the offending request
/// carried one (a malformed line has no parseable id).
#[derive(Debug, Clone, PartialEq)]
pub struct ErrorReply {
    pub id: Option<u64>,
    pub message: String,
}

/// Nearest-rank percentile summary of one latency distribution.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct DistSummary {
    pub count: u64,
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    pub max: u64,
}

impl DistSummary {
    /// The one summary shape every consumer shares: the daemon's
    /// `stats` reply, the load generator's report, and the serve
    /// bench all reduce a [`Dist`] through this, so their percentile
    /// math cannot drift apart.
    pub fn of(d: &Dist) -> DistSummary {
        if d.count() == 0 {
            return DistSummary::default();
        }
        let q = d.quantiles(&[0.50, 0.95, 0.99]);
        DistSummary {
            count: d.count() as u64,
            p50: q[0],
            p95: q[1],
            p99: q[2],
            max: d.max(),
        }
    }
}

/// The Prometheus text exposition body answering a `metrics` request.
/// Carried as one JSON string on the wire (the protocol stays
/// line-delimited JSON); clients print `text` verbatim for scraping.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsReply {
    pub text: String,
}

/// The daemon's metrics snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct StatsReply {
    pub completed: u64,
    pub rejected: u64,
    pub errors: u64,
    pub host_placements: u64,
    pub accel_placements: u64,
    /// Requests served from the memoization tiers (process memory or
    /// the on-disk trace store).
    pub hits: u64,
    /// Requests that ran a fresh simulation — zero on a warm store.
    pub fresh_sims: u64,
    pub queue: DistSummary,
    pub service: DistSummary,
    pub latency: DistSummary,
    /// The SLO the daemon judges end-to-end latency against.
    pub slo_cycles: u64,
    /// Completed jobs whose latency exceeded `slo_cycles`.
    pub slo_violations: u64,
    /// Simulated-time throughput; `None` when not meaningful (no jobs,
    /// or zero simulated cycles — the case that used to serialize as
    /// invalid JSON before non-finite floats mapped to `null`).
    pub jobs_per_sim_second: Option<f64>,
    /// Engine profile the daemon simulates with (`"reference"` or
    /// `"fast"`). Parses back as `"reference"` when absent, so replies
    /// from pre-profile daemons still decode.
    pub profile: String,
}

fn num(n: u64) -> Json {
    Json::Num(n as f64)
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn need_u64(v: &Json, key: &str) -> Result<u64, String> {
    v.get(key)
        .and_then(Json::as_u64)
        .ok_or_else(|| format!("missing or non-numeric {key:?}"))
}

fn opt_u64(v: &Json, key: &str) -> Result<Option<u64>, String> {
    match v.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(j) => j
            .as_u64()
            .map(Some)
            .ok_or_else(|| format!("non-numeric {key:?}")),
    }
}

fn need_str<'a>(v: &'a Json, key: &str) -> Result<&'a str, String> {
    v.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| format!("missing or non-string {key:?}"))
}

impl Request {
    pub fn to_json(&self) -> Json {
        match self {
            Request::Submit(s) => {
                let mut pairs = vec![
                    ("op", Json::Str("submit".into())),
                    ("id", num(s.id)),
                    ("kernel", Json::Str(s.kernel.clone())),
                ];
                if let Some(n) = s.clusters {
                    pairs.push(("clusters", num(n as u64)));
                }
                if let Some(r) = s.routine {
                    pairs.push(("routine", Json::Str(r.name().into())));
                }
                if let Some(g) = s.gap {
                    pairs.push(("gap", num(g)));
                }
                if let Some(seed) = s.seed {
                    pairs.push(("seed", num(seed)));
                }
                if let Some(tp) = &s.traceparent {
                    pairs.push(("traceparent", Json::Str(tp.clone())));
                }
                obj(pairs)
            }
            Request::Stats => obj(vec![("op", Json::Str("stats".into()))]),
            Request::Metrics => obj(vec![("op", Json::Str("metrics".into()))]),
            Request::Ping => obj(vec![("op", Json::Str("ping".into()))]),
            Request::Shutdown => obj(vec![("op", Json::Str("shutdown".into()))]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Request, String> {
        match need_str(v, "op")? {
            "submit" => {
                let routine = match v.get("routine") {
                    None | Some(Json::Null) => None,
                    Some(j) => {
                        let name = j.as_str().ok_or("non-string \"routine\"")?;
                        Some(
                            RoutineKind::parse(name)
                                .ok_or_else(|| format!("unknown routine {name:?}"))?,
                        )
                    }
                };
                let traceparent = match v.get("traceparent") {
                    None | Some(Json::Null) => None,
                    Some(j) => Some(
                        j.as_str().ok_or("non-string \"traceparent\"")?.to_string(),
                    ),
                };
                Ok(Request::Submit(Submit {
                    id: need_u64(v, "id")?,
                    kernel: need_str(v, "kernel")?.to_string(),
                    clusters: opt_u64(v, "clusters")?.map(|n| n as usize),
                    routine,
                    gap: opt_u64(v, "gap")?,
                    seed: opt_u64(v, "seed")?,
                    traceparent,
                }))
            }
            "stats" => Ok(Request::Stats),
            "metrics" => Ok(Request::Metrics),
            "ping" => Ok(Request::Ping),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(format!("unknown op {other:?}")),
        }
    }

    /// Parse one wire line.
    pub fn from_line(line: &str) -> Result<Request, String> {
        Request::from_json(&Json::parse(line)?)
    }

    /// Serialize to one wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_string()
    }
}

impl Reply {
    pub fn to_json(&self) -> Json {
        match self {
            Reply::Result(r) => {
                let mut pairs = vec![
                    ("reply", Json::Str("result".into())),
                    ("id", num(r.id)),
                    ("kernel", Json::Str(r.kernel.clone())),
                    ("routine", Json::Str(r.routine.name().into())),
                    ("cycles", num(r.cycles)),
                    ("queue_delay", num(r.queue_delay)),
                    ("latency", num(r.latency)),
                    ("start", num(r.start)),
                    ("completion", num(r.completion)),
                    ("hit", Json::Bool(r.hit)),
                ];
                match r.placement {
                    Placement::Host => pairs.push(("placement", Json::Str("host".into()))),
                    Placement::Accelerator { n_clusters } => {
                        pairs.push(("placement", Json::Str("accel".into())));
                        pairs.push(("clusters", num(n_clusters as u64)));
                    }
                }
                if let Some(src) = r.source {
                    pairs.push(("source", Json::Str(src.name().into())));
                }
                obj(pairs)
            }
            Reply::Rejected(r) => obj(vec![
                ("reply", Json::Str("rejected".into())),
                ("id", num(r.id)),
                ("reason", Json::Str(r.reason.clone())),
                ("backlog", num(r.backlog)),
                ("bound", num(r.bound)),
            ]),
            Reply::Error(e) => {
                let mut pairs = vec![
                    ("reply", Json::Str("error".into())),
                    ("message", Json::Str(e.message.clone())),
                ];
                if let Some(id) = e.id {
                    pairs.push(("id", num(id)));
                }
                obj(pairs)
            }
            Reply::Pong => obj(vec![("reply", Json::Str("pong".into()))]),
            Reply::Stats(s) => obj(vec![
                ("reply", Json::Str("stats".into())),
                ("completed", num(s.completed)),
                ("rejected", num(s.rejected)),
                ("errors", num(s.errors)),
                ("host_placements", num(s.host_placements)),
                ("accel_placements", num(s.accel_placements)),
                ("hits", num(s.hits)),
                ("fresh_sims", num(s.fresh_sims)),
                ("queue", dist_json(&s.queue)),
                ("service", dist_json(&s.service)),
                ("latency", dist_json(&s.latency)),
                ("slo_cycles", num(s.slo_cycles)),
                ("slo_violations", num(s.slo_violations)),
                // Non-finite rates serialize as null either way (the
                // json layer guarantees it); mapping them out here keeps
                // encode/decode a round trip.
                (
                    "jobs_per_sim_second",
                    match s.jobs_per_sim_second {
                        Some(r) if r.is_finite() => Json::Num(r),
                        _ => Json::Null,
                    },
                ),
                ("profile", Json::Str(s.profile.clone())),
            ]),
            Reply::Metrics(m) => obj(vec![
                ("reply", Json::Str("metrics".into())),
                ("text", Json::Str(m.text.clone())),
            ]),
            Reply::ShuttingDown { drained } => obj(vec![
                ("reply", Json::Str("shutting-down".into())),
                ("drained", num(*drained)),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Reply, String> {
        match need_str(v, "reply")? {
            "result" => {
                let placement = match need_str(v, "placement")? {
                    "host" => Placement::Host,
                    "accel" => Placement::Accelerator {
                        n_clusters: need_u64(v, "clusters")? as usize,
                    },
                    other => return Err(format!("unknown placement {other:?}")),
                };
                let routine = need_str(v, "routine")?;
                let routine = RoutineKind::parse(routine)
                    .ok_or_else(|| format!("unknown routine {routine:?}"))?;
                let source = match v.get("source") {
                    None | Some(Json::Null) => None,
                    Some(j) => {
                        let name = j.as_str().ok_or("non-string \"source\"")?;
                        Some(
                            Source::parse(name)
                                .ok_or_else(|| format!("unknown source {name:?}"))?,
                        )
                    }
                };
                Ok(Reply::Result(JobReply {
                    id: need_u64(v, "id")?,
                    kernel: need_str(v, "kernel")?.to_string(),
                    placement,
                    routine,
                    cycles: need_u64(v, "cycles")?,
                    queue_delay: need_u64(v, "queue_delay")?,
                    latency: need_u64(v, "latency")?,
                    start: need_u64(v, "start")?,
                    completion: need_u64(v, "completion")?,
                    source,
                    hit: matches!(v.get("hit"), Some(Json::Bool(true))),
                }))
            }
            "rejected" => Ok(Reply::Rejected(Rejected {
                id: need_u64(v, "id")?,
                reason: need_str(v, "reason")?.to_string(),
                backlog: need_u64(v, "backlog")?,
                bound: need_u64(v, "bound")?,
            })),
            "error" => Ok(Reply::Error(ErrorReply {
                id: opt_u64(v, "id")?,
                message: need_str(v, "message")?.to_string(),
            })),
            "pong" => Ok(Reply::Pong),
            "stats" => Ok(Reply::Stats(StatsReply {
                completed: need_u64(v, "completed")?,
                rejected: need_u64(v, "rejected")?,
                errors: need_u64(v, "errors")?,
                host_placements: need_u64(v, "host_placements")?,
                accel_placements: need_u64(v, "accel_placements")?,
                hits: need_u64(v, "hits")?,
                fresh_sims: need_u64(v, "fresh_sims")?,
                queue: dist_from_json(v.get("queue").ok_or("missing \"queue\"")?)?,
                service: dist_from_json(v.get("service").ok_or("missing \"service\"")?)?,
                latency: dist_from_json(v.get("latency").ok_or("missing \"latency\"")?)?,
                slo_cycles: need_u64(v, "slo_cycles")?,
                slo_violations: need_u64(v, "slo_violations")?,
                jobs_per_sim_second: match v.get("jobs_per_sim_second") {
                    None | Some(Json::Null) => None,
                    Some(j) => Some(j.as_f64().ok_or("non-numeric \"jobs_per_sim_second\"")?),
                },
                profile: match v.get("profile") {
                    None | Some(Json::Null) => "reference".to_string(),
                    Some(j) => j.as_str().ok_or("non-string \"profile\"")?.to_string(),
                },
            })),
            "metrics" => Ok(Reply::Metrics(MetricsReply {
                text: need_str(v, "text")?.to_string(),
            })),
            "shutting-down" => Ok(Reply::ShuttingDown {
                drained: need_u64(v, "drained")?,
            }),
            other => Err(format!("unknown reply {other:?}")),
        }
    }

    pub fn from_line(line: &str) -> Result<Reply, String> {
        Reply::from_json(&Json::parse(line)?)
    }

    pub fn to_line(&self) -> String {
        self.to_json().to_string()
    }
}

fn dist_json(d: &DistSummary) -> Json {
    obj(vec![
        ("count", num(d.count)),
        ("p50", num(d.p50)),
        ("p95", num(d.p95)),
        ("p99", num(d.p99)),
        ("max", num(d.max)),
    ])
}

fn dist_from_json(v: &Json) -> Result<DistSummary, String> {
    Ok(DistSummary {
        count: need_u64(v, "count")?,
        p50: need_u64(v, "p50")?,
        p95: need_u64(v, "p95")?,
        p99: need_u64(v, "p99")?,
        max: need_u64(v, "max")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_stats() -> StatsReply {
        StatsReply {
            completed: 10,
            rejected: 2,
            errors: 1,
            host_placements: 3,
            accel_placements: 7,
            hits: 6,
            fresh_sims: 4,
            queue: DistSummary {
                count: 7,
                p50: 10,
                p95: 90,
                p99: 99,
                max: 120,
            },
            service: DistSummary {
                count: 7,
                p50: 500,
                p95: 900,
                p99: 990,
                max: 1000,
            },
            latency: DistSummary {
                count: 10,
                p50: 510,
                p95: 990,
                p99: 1089,
                max: 1120,
            },
            slo_cycles: 1_000_000,
            slo_violations: 1,
            jobs_per_sim_second: Some(1234.5),
            profile: "reference".to_string(),
        }
    }

    #[test]
    fn stats_without_a_profile_field_decode_as_reference() {
        // Replies from pre-profile daemons stay parseable.
        let mut line = Reply::Stats(sample_stats()).to_line();
        line = line.replace(",\"profile\":\"reference\"", "");
        assert!(!line.contains("profile"), "{line}");
        match Reply::from_line(&line).unwrap() {
            Reply::Stats(s) => assert_eq!(s.profile, "reference"),
            other => panic!("expected stats, got {other:?}"),
        }
    }

    #[test]
    fn every_request_round_trips() {
        let requests = vec![
            Request::Submit(Submit {
                id: 7,
                kernel: "axpy:1024".into(),
                clusters: Some(8),
                routine: Some(RoutineKind::Multicast),
                gap: Some(120),
                seed: Some(99),
                traceparent: Some("00f1e2d3c4b5a697-0123456789abcdef".into()),
            }),
            Request::Submit(Submit {
                id: 0,
                kernel: "montecarlo:4096".into(),
                clusters: None,
                routine: None,
                gap: None,
                seed: None,
                traceparent: None,
            }),
            Request::Stats,
            Request::Metrics,
            Request::Ping,
            Request::Shutdown,
        ];
        for req in requests {
            let line = req.to_line();
            assert!(!line.contains('\n'), "{line}");
            assert_eq!(Request::from_line(&line).unwrap(), req, "{line}");
            // Deterministic bytes.
            assert_eq!(line, req.to_line());
        }
    }

    #[test]
    fn every_reply_round_trips() {
        let replies = vec![
            Reply::Result(JobReply {
                id: 7,
                kernel: "axpy:1024".into(),
                placement: Placement::Accelerator { n_clusters: 8 },
                routine: RoutineKind::Multicast,
                cycles: 12_345,
                queue_delay: 678,
                latency: 13_023,
                start: 678,
                completion: 13_023,
                source: Some(Source::Disk),
                hit: true,
            }),
            Reply::Result(JobReply {
                id: 8,
                kernel: "axpy:16".into(),
                placement: Placement::Host,
                routine: RoutineKind::Multicast,
                cycles: 144,
                queue_delay: 0,
                latency: 144,
                start: 0,
                completion: 144,
                source: None,
                hit: false,
            }),
            Reply::Rejected(Rejected {
                id: 9,
                reason: "overloaded".into(),
                backlog: 16,
                bound: 16,
            }),
            Reply::Error(ErrorReply {
                id: Some(3),
                message: "bad kernel \"axpy:\"".into(),
            }),
            Reply::Error(ErrorReply {
                id: None,
                message: "unparseable line".into(),
            }),
            Reply::Pong,
            Reply::Stats(sample_stats()),
            Reply::Metrics(MetricsReply {
                // Exposition text is newline-heavy and quote-heavy; the
                // wire escaping must keep it one line and bring it back
                // byte-identical.
                text: "# HELP occamy_serve_completed_total x\n# TYPE occamy_serve_completed_total counter\noccamy_serve_completed_total 3\noccamy_serve_requests_total{outcome=\"rejected\"} 1\n".into(),
            }),
            Reply::ShuttingDown { drained: 12 },
        ];
        for reply in replies {
            let line = reply.to_line();
            assert!(!line.contains('\n'), "{line}");
            assert_eq!(Reply::from_line(&line).unwrap(), reply, "{line}");
            assert_eq!(line, reply.to_line());
        }
    }

    #[test]
    fn infinite_throughput_serializes_as_null_and_parses_back() {
        // The satellite fix end-to-end: a degenerate rate must neither
        // break the wire nor the parser.
        let mut s = sample_stats();
        s.jobs_per_sim_second = Some(f64::INFINITY);
        let line = Reply::Stats(s).to_line();
        assert!(line.contains("\"jobs_per_sim_second\":null"), "{line}");
        match Reply::from_line(&line).unwrap() {
            Reply::Stats(parsed) => assert_eq!(parsed.jobs_per_sim_second, None),
            other => panic!("expected stats, got {other:?}"),
        }
    }

    #[test]
    fn dist_summary_of_matches_dist_quantiles() {
        let mut d = Dist::default();
        for v in 1..=100u64 {
            d.record(v);
        }
        let s = DistSummary::of(&d);
        assert_eq!(s.count, 100);
        assert_eq!(s.p50, d.quantile(0.50));
        assert_eq!(s.p95, d.quantile(0.95));
        assert_eq!(s.p99, d.quantile(0.99));
        assert_eq!(s.max, 100);
        assert_eq!(DistSummary::of(&Dist::default()), DistSummary::default());
    }

    #[test]
    fn rejected_reply_names_overloaded() {
        let r = Reply::Rejected(Rejected {
            id: 1,
            reason: "overloaded".into(),
            backlog: 4,
            bound: 4,
        });
        assert!(r.to_line().contains("\"reason\":\"overloaded\""));
    }

    #[test]
    fn malformed_lines_are_errors_not_panics() {
        for bad in [
            "",
            "{",
            "[1,2,3]",
            "{\"op\":\"frobnicate\"}",
            "{\"op\":\"submit\"}",
            "{\"op\":\"submit\",\"id\":1,\"kernel\":\"axpy:64\",\"routine\":\"warp\"}",
            "{\"reply\":\"result\"}",
            "\u{1}\u{2}garbage bytes\u{3}",
        ] {
            assert!(Request::from_line(bad).is_err(), "{bad:?}");
        }
        assert!(Reply::from_line("{\"reply\":\"nope\"}").is_err());
    }
}
