//! On-chip memories: per-cluster TCDM and the system-level SPMs (§3.1).

pub mod spm;
pub mod tcdm;

pub use spm::Spm;
pub use tcdm::Tcdm;
