//! System-level Scratch-Pad Memories (§3.1): the 1 MiB wide SPM (512-bit
//! port, operand staging for jobs per the paper's §4.1 assumptions) and
//! the 512 KiB narrow SPM. Functional storage; the wide port's timing
//! contention is the `PsPort` of the DES (§5.5.E: single read port).

#[derive(Debug, Clone)]
pub struct Spm {
    data: Vec<u8>,
    reads: u64,
    writes: u64,
}

impl Spm {
    pub fn new(bytes: u64) -> Self {
        Self {
            data: vec![0; bytes as usize],
            reads: 0,
            writes: 0,
        }
    }

    /// The wide SPM of the paper's configuration (1 MiB).
    pub fn occamy_wide() -> Self {
        Self::new(1024 * 1024)
    }

    /// The narrow SPM (512 KiB).
    pub fn occamy_narrow() -> Self {
        Self::new(512 * 1024)
    }

    pub fn len(&self) -> u64 {
        self.data.len() as u64
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn write(&mut self, offset: u64, bytes: &[u8]) {
        let o = offset as usize;
        assert!(o + bytes.len() <= self.data.len(), "SPM write out of bounds");
        self.data[o..o + bytes.len()].copy_from_slice(bytes);
        self.writes += 1;
    }

    pub fn read(&mut self, offset: u64, len: u64) -> &[u8] {
        let o = offset as usize;
        assert!(o + len as usize <= self.data.len(), "SPM read out of bounds");
        self.reads += 1;
        &self.data[o..o + len as usize]
    }

    /// Store an f64 slice (the operand layout used by the jobs).
    pub fn write_f64(&mut self, offset: u64, values: &[f64]) {
        let mut bytes = Vec::with_capacity(values.len() * 8);
        for v in values {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        self.write(offset, &bytes);
    }

    /// Load an f64 slice.
    pub fn read_f64(&mut self, offset: u64, count: usize) -> Vec<f64> {
        let raw = self.read(offset, count as u64 * 8).to_vec();
        raw.chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    pub fn access_counts(&self) -> (u64, u64) {
        (self.reads, self.writes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occamy_sizes() {
        assert_eq!(Spm::occamy_wide().len(), 1024 * 1024);
        assert_eq!(Spm::occamy_narrow().len(), 512 * 1024);
    }

    #[test]
    fn f64_roundtrip() {
        let mut s = Spm::occamy_wide();
        let v = vec![1.5, -2.25, 3.0, f64::MIN_POSITIVE];
        s.write_f64(0x40, &v);
        assert_eq!(s.read_f64(0x40, 4), v);
    }

    #[test]
    fn access_counting() {
        let mut s = Spm::new(1024);
        s.write(0, &[1, 2, 3]);
        s.read(0, 2);
        s.read(1, 2);
        assert_eq!(s.access_counts(), (2, 1));
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn oob_read_panics() {
        let mut s = Spm::new(16);
        s.read(10, 8);
    }
}
