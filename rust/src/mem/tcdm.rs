//! Tightly-Coupled Data Memory model (§3.1).
//!
//! Each cluster owns 128 KiB of TCDM divided into 32 banks with word-level
//! interleaving. The model is functional (byte-addressable storage used to
//! hold job descriptors and operand tiles) plus bank-conflict accounting;
//! port-level *timing* contention is handled by the DES servers in
//! `sim::server`.

/// Word size of a TCDM bank port (64-bit, one double per access).
pub const BANK_WORD: u64 = 8;

#[derive(Debug, Clone)]
pub struct Tcdm {
    data: Vec<u8>,
    n_banks: usize,
    /// Per-bank access counters (conflict/pressure accounting).
    bank_accesses: Vec<u64>,
}

impl Tcdm {
    pub fn new(bytes: u64, n_banks: usize) -> Self {
        assert!(n_banks.is_power_of_two(), "bank count must be 2^k");
        assert_eq!(bytes % (n_banks as u64 * BANK_WORD), 0);
        Self {
            data: vec![0; bytes as usize],
            n_banks,
            bank_accesses: vec![0; n_banks],
        }
    }

    /// Paper default: 128 KiB in 32 banks.
    pub fn occamy() -> Self {
        Self::new(128 * 1024, 32)
    }

    pub fn len(&self) -> u64 {
        self.data.len() as u64
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn n_banks(&self) -> usize {
        self.n_banks
    }

    /// Bank index of a byte offset (word-interleaved).
    pub fn bank_of(&self, offset: u64) -> usize {
        ((offset / BANK_WORD) % self.n_banks as u64) as usize
    }

    pub fn write(&mut self, offset: u64, bytes: &[u8]) {
        let o = offset as usize;
        assert!(
            o + bytes.len() <= self.data.len(),
            "TCDM write out of bounds: {o}+{} > {}",
            bytes.len(),
            self.data.len()
        );
        self.data[o..o + bytes.len()].copy_from_slice(bytes);
        self.count_banks(offset, bytes.len() as u64);
    }

    pub fn read(&mut self, offset: u64, len: u64) -> &[u8] {
        let o = offset as usize;
        assert!(
            o + len as usize <= self.data.len(),
            "TCDM read out of bounds"
        );
        self.count_banks(offset, len);
        &self.data[o..o + len as usize]
    }

    pub fn write_u64(&mut self, offset: u64, v: u64) {
        self.write(offset, &v.to_le_bytes());
    }

    pub fn read_u64(&mut self, offset: u64) -> u64 {
        let b: [u8; 8] = self.read(offset, 8).try_into().unwrap();
        u64::from_le_bytes(b)
    }

    fn count_banks(&mut self, offset: u64, len: u64) {
        let first = offset / BANK_WORD;
        let last = (offset + len.max(1) - 1) / BANK_WORD;
        for w in first..=last {
            let b = (w % self.n_banks as u64) as usize;
            self.bank_accesses[b] += 1;
        }
    }

    /// Access count per bank since construction.
    pub fn bank_accesses(&self) -> &[u64] {
        &self.bank_accesses
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occamy_geometry() {
        let t = Tcdm::occamy();
        assert_eq!(t.len(), 128 * 1024);
        assert_eq!(t.n_banks(), 32);
    }

    #[test]
    fn rw_roundtrip() {
        let mut t = Tcdm::occamy();
        t.write_u64(0x100, 0xdead_beef_cafe_f00d);
        assert_eq!(t.read_u64(0x100), 0xdead_beef_cafe_f00d);
    }

    #[test]
    fn word_interleaved_banks() {
        let t = Tcdm::occamy();
        assert_eq!(t.bank_of(0), 0);
        assert_eq!(t.bank_of(8), 1);
        assert_eq!(t.bank_of(8 * 32), 0); // wraps after 32 words
        assert_eq!(t.bank_of(8 * 33), 1);
    }

    #[test]
    fn sequential_access_spreads_across_banks() {
        let mut t = Tcdm::occamy();
        t.write(0, &vec![0u8; 32 * 8]); // exactly one word per bank
        assert!(t.bank_accesses().iter().all(|&c| c == 1));
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn oob_write_panics() {
        let mut t = Tcdm::occamy();
        t.write(128 * 1024 - 4, &[0u8; 8]);
    }
}
