//! Campaign quickstart: parse `examples/campaign.toml`, execute it as
//! two cooperating shards with a persistent trace store, merge the
//! streamed results, and prove the merge is bit-identical to a
//! single-process run — then render the triples.
//!
//! ```bash
//! cargo run --release --example campaign_demo
//! ```
//!
//! The same flow is available from the CLI (and across real processes)
//! as `occamy campaign <run|merge|status|validate>`; see the spec file
//! for the command lines.

use occamy_offload::campaign::{self, CampaignSpec, Shard, TraceStore};

fn main() -> anyhow::Result<()> {
    let spec = CampaignSpec::parse(include_str!("campaign.toml"))?;

    // Dry-run diagnostics: what would this campaign execute?
    println!("{}\n", spec.report());

    let out = std::env::temp_dir().join(format!("occamy-campaign-demo-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&out);
    let store = TraceStore::open(out.join("store"))?;

    // Two shards, deterministically partitioned — in production these
    // are separate `occamy campaign run --shard i/N` processes (the
    // store then shares traces across them on disk).
    for i in 0..2 {
        let report = campaign::run_shard(&spec, Shard::new(i, 2)?, &out, Some(&store))?;
        println!("{report}");
    }
    println!("status:\n{}", campaign::status(&spec, 2, &out)?);

    // Merge the streamed JSONL back into input-ordered SweepResults and
    // verify the tentpole guarantee.
    let merged = campaign::merge(&spec, 2, &out)?;
    let single = campaign::run_single(&spec);
    assert_eq!(merged, single, "merge must be bit-identical to one process");
    println!(
        "merged {} points; bit-identical to single-process execution",
        merged.len()
    );
    let stats = store.stats();
    println!(
        "store: {} memory hit(s), {} disk hit(s), {} simulation(s)\n",
        stats.memory_hits, stats.disk_hits, stats.simulations
    );

    println!(
        "{:>12} {:>9} {:>9} {:>10} {:>9}",
        "kernel", "clusters", "overhead", "idealSp", "achieved"
    );
    for t in merged.triples() {
        println!(
            "{:>12} {:>9} {:>9} {:>10.2} {:>9.2}",
            t.spec.id(),
            t.n_clusters,
            t.runtimes.overhead(),
            t.runtimes.ideal_speedup(),
            t.runtimes.achieved_speedup()
        );
    }

    let _ = std::fs::remove_dir_all(&out);
    Ok(())
}
