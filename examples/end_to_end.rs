//! End-to-end driver — proves all layers compose on a real workload.
//!
//! Boots the full stack: the cycle-level Occamy DES (L3 timing), the PJRT
//! runtime with the AOT-compiled JAX/Pallas kernels (L1/L2 numerics, via
//! `make artifacts`), and the coordinator (queueing, model-driven offload
//! decision, JCU completion tracking). Streams a mixed trace of several
//! hundred jobs across all six kernels, verifies every result against the
//! native references, and reports latency/throughput. The run is recorded
//! in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use occamy_offload::config::Config;
use occamy_offload::coordinator::{Coordinator, CoordinatorConfig, JobRequest, Placement};
use occamy_offload::kernels::JobSpec;
use occamy_offload::runtime::default_artifacts_dir;

fn main() -> anyhow::Result<()> {
    let n_jobs: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(350);

    // The job mix: every kernel family, sizes matching the AOT'd
    // artifact variants, weighted toward the fine-grained jobs the
    // paper's optimizations target.
    let mix: Vec<JobSpec> = vec![
        JobSpec::Axpy { n: 256 },
        JobSpec::Axpy { n: 1024 },
        JobSpec::Axpy { n: 4096 },
        JobSpec::Matmul { m: 16, n: 16, k: 16 },
        JobSpec::Matmul { m: 64, n: 64, k: 64 },
        JobSpec::Atax { m: 64, n: 64 },
        JobSpec::Atax { m: 128, n: 128 },
        JobSpec::Covariance { m: 32, n: 64 },
        JobSpec::MonteCarlo { samples: 1024 },
        JobSpec::MonteCarlo { samples: 16384 },
        JobSpec::Bfs { nodes: 64, levels: 4 },
        JobSpec::Bfs { nodes: 128, levels: 4 },
    ];

    let artifacts = default_artifacts_dir();
    println!("artifacts: {} | jobs: {n_jobs}", artifacts.display());
    let coord = Coordinator::start(
        CoordinatorConfig {
            cfg: Config::default(),
            queue_depth: 32,
            timing_only: false,
            ..Default::default()
        },
        Some(&artifacts),
    )?;

    let t0 = std::time::Instant::now();
    // Submit from a separate thread through a cloned handle so the
    // bounded queue's backpressure is actually exercised.
    let submitter = coord.submitter();
    let reqs: Vec<JobRequest> = mix
        .iter()
        .cycle()
        .take(n_jobs as usize)
        .enumerate()
        .map(|(i, spec)| JobRequest::new(i as u64, *spec))
        .collect();
    let submit_thread = std::thread::spawn(move || {
        for r in reqs {
            submitter.submit(r).expect("submit");
        }
    });
    // Drain results on this thread.
    let mut verified = 0u64;
    let mut failures = 0u64;
    let mut host = 0u64;
    let mut accel_clusters = std::collections::BTreeMap::<usize, u64>::new();
    for _ in 0..n_jobs {
        let r = coord.recv().expect("result");
        if r.verified {
            verified += 1;
        } else {
            failures += 1;
            eprintln!("FAIL: job {} {:?}", r.id, r.spec);
        }
        match r.placement {
            Placement::Host => host += 1,
            Placement::Accelerator { n_clusters } => {
                *accel_clusters.entry(n_clusters).or_default() += 1
            }
        }
    }
    submit_thread.join().expect("submitter");
    let wall = t0.elapsed();
    let metrics = coord.shutdown();

    println!("\n=== end-to-end run ===");
    println!("{}", metrics.summary());
    println!("placements: {host} host, accel by clusters: {accel_clusters:?}");
    println!(
        "wall: {:.2}s -> {:.1} jobs/s | sim throughput: {:.0} jobs/sim-second",
        wall.as_secs_f64(),
        n_jobs as f64 / wall.as_secs_f64(),
        metrics.jobs_per_sim_second()
    );
    println!(
        "verification: {verified}/{n_jobs} OK ({} failures)",
        failures
    );
    anyhow::ensure!(failures == 0, "verification failures");
    println!("END-TO-END OK");
    Ok(())
}
