//! Internal calibration probe (kept as an example of raw triple runs
//! through the cached sweep API).
use occamy_offload::config::Config;
use occamy_offload::kernels::JobSpec;
use occamy_offload::sweep;

fn main() {
    let cfg = Config::default();
    let specs = [
        ("axpy1024", JobSpec::Axpy { n: 1024 }),
        ("mc16k", JobSpec::MonteCarlo { samples: 16384 }),
        ("matmul16", JobSpec::Matmul { m: 16, n: 16, k: 16 }),
        ("atax64", JobSpec::Atax { m: 64, n: 64 }),
        ("cov32x64", JobSpec::Covariance { m: 32, n: 64 }),
        ("bfs64", JobSpec::Bfs { nodes: 64, levels: 4 }),
    ];
    println!("{:<10} {:>3} {:>8} {:>8} {:>8} {:>9} {:>9} {:>6} {:>6} {:>5}",
        "kernel", "n", "base", "ideal", "improved", "overhead", "residual", "idSp", "achSp", "rest");
    for (name, spec) in &specs {
        for n in [1usize, 2, 4, 8, 16, 32] {
            let t = sweep::triple(&cfg, spec, n);
            println!("{:<10} {:>3} {:>8} {:>8} {:>8} {:>9} {:>9} {:>6.2} {:>6.2} {:>5.2}",
                name, n, t.base, t.ideal, t.improved, t.overhead(), t.residual_overhead(),
                t.ideal_speedup(), t.achieved_speedup(), t.restored_fraction());
        }
    }
}
