//! Smoke: load every artifact, execute with generated inputs, verify
//! against the native references.
use occamy_offload::kernels::JobSpec;
use occamy_offload::runtime::{default_artifacts_dir, run_and_verify, PjrtRuntime};

fn main() -> anyhow::Result<()> {
    let rt = PjrtRuntime::new(&default_artifacts_dir())?;
    println!("platform: {}", rt.platform());
    let specs = [
        JobSpec::Axpy { n: 1024 },
        JobSpec::Matmul { m: 64, n: 64, k: 64 },
        JobSpec::Atax { m: 64, n: 64 },
        JobSpec::Covariance { m: 32, n: 64 },
        JobSpec::MonteCarlo { samples: 4096 },
        JobSpec::Bfs { nodes: 64, levels: 4 },
    ];
    for spec in &specs {
        let out = run_and_verify(&rt, spec, 42)?;
        println!("{:<22} verified ({} output tensors)", spec.id(), out.len());
    }
    println!("runtime smoke OK ({} executables cached)", rt.cached());
    Ok(())
}
