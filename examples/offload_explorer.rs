//! Offload-decision explorer: the paper's motivating scenario (§1) —
//! "when", "where" and "how" to offload.
//!
//! For a grid of kernels and problem sizes, prints the model-driven
//! planner's decision (host vs accelerator, and the optimal cluster
//! count), next to the simulated runtimes that justify it — the
//! "offload decision as an optimization problem" of §5.6.
//!
//! ```bash
//! cargo run --release --example offload_explorer
//! ```

use occamy_offload::config::Config;
use occamy_offload::coordinator::{Placement, Planner};
use occamy_offload::kernels::JobSpec;
use occamy_offload::offload::RoutineKind;
use occamy_offload::sweep::{self, OffloadRequest};

fn main() {
    let cfg = Config::default();
    let planner = Planner::new(&cfg);

    let grid: Vec<(&str, Vec<JobSpec>)> = vec![
        (
            "axpy",
            [64u64, 256, 1024, 4096, 16384]
                .iter()
                .map(|&n| JobSpec::Axpy { n })
                .collect(),
        ),
        (
            "montecarlo",
            [256u64, 1024, 8192, 65536]
                .iter()
                .map(|&samples| JobSpec::MonteCarlo { samples })
                .collect(),
        ),
        (
            "matmul",
            [8u64, 16, 32, 64]
                .iter()
                .map(|&s| JobSpec::Matmul { m: s, n: s, k: s })
                .collect(),
        ),
        (
            "atax",
            [16u64, 64, 256]
                .iter()
                .map(|&s| JobSpec::Atax { m: s, n: s })
                .collect(),
        ),
    ];

    println!(
        "{:<12} {:>9} {:>10} {:>12} {:>12} {:>10}",
        "kernel", "size", "host(cy)", "decision", "model(cy)", "sim(cy)"
    );
    for (name, specs) in grid {
        for spec in specs {
            let plan = planner.plan(&spec);
            let size = match spec {
                JobSpec::Axpy { n } => n,
                JobSpec::MonteCarlo { samples } => samples,
                JobSpec::Matmul { m, .. } => m,
                JobSpec::Atax { m, .. } => m,
                _ => 0,
            };
            let (decision, sim) = match plan.placement {
                Placement::Host => ("host".to_string(), plan.host_estimate),
                Placement::Accelerator { n_clusters } => (
                    format!("{n_clusters} clusters"),
                    sweep::run_one(
                        &cfg,
                        OffloadRequest::new(spec, n_clusters, RoutineKind::Multicast),
                    )
                    .total,
                ),
            };
            println!(
                "{:<12} {:>9} {:>10} {:>12} {:>12} {:>10}",
                name, size, plan.host_estimate, decision, plan.estimate, sim
            );
        }
    }
    println!(
        "\nThe planner offloads only when the Eq.-4 estimate beats the host,\n\
         picks few clusters for broadcast-bound kernels (ATAX class) and\n\
         many for Amdahl-class kernels — exactly the paper's two regimes."
    );
}
