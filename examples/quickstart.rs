//! Quickstart: simulate one offloaded job in all three variants and print
//! the paper's headline metrics for it.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use occamy_offload::config::Config;
use occamy_offload::kernels::JobSpec;
use occamy_offload::model::OffloadModel;
use occamy_offload::offload::run_triple;

fn main() {
    // The simulated SoC: Occamy's 8 quadrants x 4 clusters x (8+1) cores
    // with the paper's calibrated timing constants. Everything is
    // overridable via Config::from_toml — try `occamy config-dump`.
    let cfg = Config::default();
    println!(
        "SoC: {} clusters, {} accelerator cores\n",
        cfg.soc.n_clusters(),
        cfg.soc.n_accel_cores()
    );

    // A fine-grained AXPY — the class of job the paper's optimizations
    // target (§5.4: fine-grained heterogeneous tasks benefit the most).
    let spec = JobSpec::Axpy { n: 1024 };
    println!("job: {:?} ({} flops)\n", spec, spec.flops());

    println!(
        "{:>8}  {:>8}  {:>8}  {:>9}  {:>9}  {:>7}  {:>8}",
        "clusters", "base", "improved", "ideal", "overhead", "idealSp", "achieved"
    );
    for n in [1usize, 2, 4, 8, 16, 32] {
        let t = run_triple(&cfg, &spec, n).runtimes(n);
        println!(
            "{:>8}  {:>8}  {:>8}  {:>9}  {:>9}  {:>7.2}  {:>8.2}",
            n,
            t.base,
            t.improved,
            t.ideal,
            t.overhead(),
            t.ideal_speedup(),
            t.achieved_speedup()
        );
    }

    // The analytical model (Eq. 4/5): what the offload decision would use.
    let model = OffloadModel::new(&cfg);
    println!(
        "\nmodel estimate at 8 clusters: {} cycles (Eq. 4 composition)",
        model.estimate(&spec, 8)
    );
    println!("run `occamy experiment all` for the full figure suite");
}
