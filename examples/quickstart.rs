//! Quickstart: declare one sweep campaign over the paper's running
//! example and print its headline metrics — the snippet mirrored in the
//! `sweep` module docs.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use occamy_offload::config::Config;
use occamy_offload::kernels::JobSpec;
use occamy_offload::model::OffloadModel;
use occamy_offload::sweep::Sweep;

fn main() {
    // The simulated SoC: Occamy's 8 quadrants x 4 clusters x (8+1) cores
    // with the paper's calibrated timing constants. Everything is
    // overridable via Config::from_toml — try `occamy config-dump`.
    let cfg = Config::default();
    println!(
        "SoC: {} clusters, {} accelerator cores\n",
        cfg.soc.n_clusters(),
        cfg.soc.n_accel_cores()
    );

    // A fine-grained AXPY — the class of job the paper's optimizations
    // target (§5.4: fine-grained heterogeneous tasks benefit the most).
    let spec = JobSpec::Axpy { n: 1024 };
    println!("job: {:?} ({} flops)\n", spec, spec.flops());

    // One declarative campaign: the base/ideal/improved triple across
    // the cluster sweep, executed in parallel with deterministic,
    // input-ordered results.
    let results = Sweep::new()
        .kernel("axpy", spec)
        .clusters([1, 2, 4, 8, 16, 32])
        .triples()
        .run(&cfg);

    println!(
        "{:>8}  {:>8}  {:>8}  {:>9}  {:>9}  {:>7}  {:>8}",
        "clusters", "base", "improved", "ideal", "overhead", "idealSp", "achieved"
    );
    for t in results.triples() {
        let r = &t.runtimes;
        println!(
            "{:>8}  {:>8}  {:>8}  {:>9}  {:>9}  {:>7.2}  {:>8.2}",
            t.n_clusters,
            r.base,
            r.improved,
            r.ideal,
            r.overhead(),
            r.ideal_speedup(),
            r.achieved_speedup()
        );
    }

    // The analytical model (Eq. 4/5): what the offload decision would use.
    let model = OffloadModel::new(&cfg);
    println!(
        "\nmodel estimate at 8 clusters: {} cycles (Eq. 4 composition)",
        model.estimate(&spec, 8)
    );

    // Contention as an axis: the same job replayed with several in
    // flight, contending for the 32-cluster fabric and the JCU's slots.
    // Latency decomposes as isolated cycles + queueing delay; the
    // inflight = 1 row is the serial coordinator (zero delay).
    println!("\n{:>8}  {:>9}  {:>10}  {:>9}", "inflight", "service", "queue_mean", "latency");
    for s in Sweep::new()
        .kernel("axpy", spec)
        .clusters([16])
        .routines([occamy_offload::offload::RoutineKind::Multicast])
        .inflight([1, 2, 4, 8])
        .run_interference(&cfg, 16, 0)
    {
        println!(
            "{:>8}  {:>9}  {:>10.0}  {:>9.0}",
            s.point.ireq.inflight,
            s.outcome.isolated,
            s.outcome.mean_queue_delay(),
            s.outcome.mean_latency()
        );
    }
    println!("\nrun `occamy experiment all` for the full figure suite");
    println!("run `occamy interfere --kernel axpy --size 1024` for contention curves");
}
