//! Model-fit scenario: recover the paper's Eq. 5 closed form from the
//! simulator, then validate it (Fig. 12-style) out of sample.
//!
//! Fits t̂(n, N) = K + a*N + b*N/n by least squares on a training grid of
//! simulated multicast AXPY offloads, prints the fitted coefficients next
//! to Eq. 5's (400, 1/4, 2.47/8), and reports the relative error on a
//! held-out grid.
//!
//! ```bash
//! cargo run --release --example model_fit
//! ```

use occamy_offload::config::Config;
use occamy_offload::kernels::JobSpec;
use occamy_offload::offload::RoutineKind;
use occamy_offload::sweep::{self, OffloadRequest};

/// Solve the 3x3 normal equations for y ~ K + a*x1 + b*x2.
fn lstsq3(rows: &[(f64, f64, f64)]) -> (f64, f64, f64) {
    // Accumulate X^T X and X^T y with X = [1, x1, x2].
    let mut m = [[0.0f64; 3]; 3];
    let mut v = [0.0f64; 3];
    for &(x1, x2, y) in rows {
        let x = [1.0, x1, x2];
        for i in 0..3 {
            for j in 0..3 {
                m[i][j] += x[i] * x[j];
            }
            v[i] += x[i] * y;
        }
    }
    // Gaussian elimination.
    for col in 0..3 {
        let piv = (col..3)
            .max_by(|&a, &b| m[a][col].abs().total_cmp(&m[b][col].abs()))
            .unwrap();
        m.swap(col, piv);
        v.swap(col, piv);
        for row in 0..3 {
            if row != col {
                let f = m[row][col] / m[col][col];
                for k in 0..3 {
                    m[row][k] -= f * m[col][k];
                }
                v[row] -= f * v[col];
            }
        }
    }
    (v[0] / m[0][0], v[1] / m[1][1], v[2] / m[2][2])
}

fn main() {
    let cfg = Config::default();
    let sim = |n: usize, nn: u64| {
        let req = OffloadRequest::new(JobSpec::Axpy { n: nn }, n, RoutineKind::Multicast);
        sweep::run_one(&cfg, req).total as f64
    };

    // Training grid.
    let mut rows = Vec::new();
    for &nn in &[128u64, 256, 512, 1024] {
        for &n in &[1usize, 2, 4, 8, 16, 32] {
            rows.push((nn as f64, nn as f64 / n as f64, sim(n, nn)));
        }
    }
    let (k, a, b) = lstsq3(&rows);
    println!("fitted  : t = {k:.0} + {a:.4}*N + {b:.4}*N/n");
    println!("Eq. 5   : t = 400 + {:.4}*N + {:.4}*N/n", 0.25, 2.47 / 8.0);
    println!(
        "(constants differ by the calibration delta documented in EXPERIMENTS.md)\n"
    );

    // Out-of-sample validation.
    println!("{:>6} {:>4} {:>10} {:>10} {:>7}", "N", "n", "sim", "fit", "err%");
    let mut max_err: f64 = 0.0;
    for &nn in &[192u64, 384, 768, 1536, 2048] {
        for &n in &[1usize, 4, 16, 32] {
            let t = sim(n, nn);
            let f = k + a * nn as f64 + b * nn as f64 / n as f64;
            let err = (t - f).abs() / t;
            max_err = max_err.max(err);
            println!("{nn:>6} {n:>4} {t:>10.0} {f:>10.0} {:>7.2}", err * 100.0);
        }
    }
    println!("\nmax out-of-sample error: {:.1}% (paper: <15%)", max_err * 100.0);
    assert!(max_err < 0.15, "fit should satisfy the paper's bound");
}
