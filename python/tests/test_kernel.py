"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes (and seeds) per kernel; every case asserts
allclose against ref.py at double precision tolerances.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref
from compile.kernels.common import choose_block

SETTINGS = dict(max_examples=20, deadline=None)


def rand(key, shape, dtype=jnp.float64):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=dtype)


# ---------------------------------------------------------------- axpy


@settings(**SETTINGS)
@given(
    n=st.integers(1, 4096),
    seed=st.integers(0, 2**31 - 1),
    alpha=st.floats(-1e3, 1e3, allow_nan=False),
)
def test_axpy_matches_ref(n, seed, alpha):
    x = rand(seed, (n,))
    y = rand(seed + 1, (n,))
    got = kernels.axpy(alpha, x, y)
    want = ref.axpy_ref(alpha, x, y)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_axpy_dtypes(dtype):
    x = rand(0, (256,), dtype)
    y = rand(1, (256,), dtype)
    got = kernels.axpy(2.0, x, y)
    assert got.dtype == dtype
    np.testing.assert_allclose(got, ref.axpy_ref(2.0, x, y), rtol=1e-5)


def test_axpy_rejects_mismatched_shapes():
    with pytest.raises(ValueError):
        kernels.axpy(1.0, jnp.ones(4), jnp.ones(5))


def test_axpy_explicit_block():
    x = rand(0, (1024,))
    y = rand(1, (1024,))
    for blk in (32, 128, 1024):
        np.testing.assert_allclose(
            kernels.axpy(1.5, x, y, block=blk), ref.axpy_ref(1.5, x, y), rtol=1e-12
        )


# ---------------------------------------------------------------- matmul


@settings(**SETTINGS)
@given(
    m=st.integers(1, 96),
    n=st.integers(1, 96),
    k=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, n, k, seed):
    a = rand(seed, (m, k))
    b = rand(seed + 1, (k, n))
    np.testing.assert_allclose(
        kernels.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-10, atol=1e-10
    )


def test_matmul_identity():
    a = rand(3, (64, 64))
    np.testing.assert_allclose(
        kernels.matmul(a, jnp.eye(64, dtype=jnp.float64)), a, rtol=1e-12
    )


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        kernels.matmul(jnp.ones((4, 5)), jnp.ones((4, 5)))


def test_matmul_f32():
    a = rand(0, (32, 32), jnp.float32)
    b = rand(1, (32, 32), jnp.float32)
    np.testing.assert_allclose(
        kernels.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4
    )


# ---------------------------------------------------------------- atax


@settings(**SETTINGS)
@given(m=st.integers(1, 128), n=st.integers(1, 128), seed=st.integers(0, 2**31 - 1))
def test_atax_matches_ref(m, n, seed):
    a = rand(seed, (m, n))
    x = rand(seed + 1, (n,))
    np.testing.assert_allclose(
        kernels.atax(a, x), ref.atax_ref(a, x), rtol=1e-9, atol=1e-9
    )


def test_atax_zero_vector():
    a = rand(0, (64, 64))
    np.testing.assert_allclose(
        kernels.atax(a, jnp.zeros(64, jnp.float64)), jnp.zeros(64), atol=0
    )


def test_atax_rejects_bad_shapes():
    with pytest.raises(ValueError):
        kernels.atax(jnp.ones((4, 5)), jnp.ones(4))


# ---------------------------------------------------------------- covariance


@settings(**SETTINGS)
@given(m=st.integers(1, 64), n=st.integers(2, 128), seed=st.integers(0, 2**31 - 1))
def test_covariance_matches_ref(m, n, seed):
    d = rand(seed, (m, n))
    np.testing.assert_allclose(
        kernels.covariance(d), ref.covariance_ref(d), rtol=1e-9, atol=1e-9
    )


def test_covariance_matches_numpy():
    d = rand(7, (16, 64))
    np.testing.assert_allclose(
        kernels.covariance(d), np.cov(np.asarray(d)), rtol=1e-9, atol=1e-9
    )


def test_covariance_is_symmetric_psd():
    d = rand(11, (24, 96))
    c = np.asarray(kernels.covariance(d))
    np.testing.assert_allclose(c, c.T, atol=1e-12)
    eig = np.linalg.eigvalsh(c)
    assert eig.min() > -1e-9


def test_covariance_rejects_single_sample():
    with pytest.raises(ValueError):
        kernels.covariance(jnp.ones((4, 1)))


# ---------------------------------------------------------------- montecarlo


@settings(**SETTINGS)
@given(n=st.integers(1, 8192), seed=st.integers(0, 2**31 - 1))
def test_montecarlo_matches_ref(n, seed):
    pts = jax.random.uniform(jax.random.PRNGKey(seed), (2, n), dtype=jnp.float64)
    np.testing.assert_allclose(
        kernels.montecarlo(pts), ref.montecarlo_ref(pts), rtol=1e-12
    )


def test_montecarlo_converges_to_pi():
    pts = jax.random.uniform(jax.random.PRNGKey(0), (2, 1 << 16), dtype=jnp.float64)
    assert abs(float(kernels.montecarlo(pts)) - np.pi) < 0.05


def test_montecarlo_all_inside_outside():
    inside = jnp.zeros((2, 128), jnp.float64) + 0.1
    assert float(kernels.montecarlo(inside)) == 4.0
    outside = jnp.ones((2, 128), jnp.float64) * 0.9
    assert float(kernels.montecarlo(outside)) == 0.0


# ---------------------------------------------------------------- bfs


def random_adj(n, p, seed, symmetric=True):
    a = (jax.random.uniform(jax.random.PRNGKey(seed), (n, n)) < p).astype(
        jnp.float64
    )
    a = a * (1 - jnp.eye(n, dtype=jnp.float64))
    if symmetric:
        a = jnp.maximum(a, a.T)
    return a


@settings(**SETTINGS)
@given(
    n=st.integers(2, 96),
    p=st.floats(0.0, 0.3),
    seed=st.integers(0, 2**31 - 1),
    symmetric=st.booleans(),
)
def test_bfs_matches_ref(n, p, seed, symmetric):
    adj = random_adj(n, p, seed, symmetric)
    src = seed % n
    np.testing.assert_array_equal(kernels.bfs(adj, src), ref.bfs_ref(adj, src))


def test_bfs_path_graph():
    n = 32
    adj = jnp.zeros((n, n), jnp.float64)
    for i in range(n - 1):
        adj = adj.at[i, i + 1].set(1.0).at[i + 1, i].set(1.0)
    dist = np.asarray(kernels.bfs(adj, 0))
    np.testing.assert_array_equal(dist, np.arange(n))


def test_bfs_disconnected():
    adj = jnp.zeros((16, 16), jnp.float64)
    dist = np.asarray(kernels.bfs(adj, 3))
    assert dist[3] == 0 and (dist[np.arange(16) != 3] == -1).all()


def test_bfs_matches_networkx_style_check():
    # complete graph: every node at distance 1
    n = 24
    adj = jnp.ones((n, n), jnp.float64) - jnp.eye(n, dtype=jnp.float64)
    dist = np.asarray(kernels.bfs(adj, 5))
    assert dist[5] == 0 and (np.delete(dist, 5) == 1).all()


# ---------------------------------------------------------------- common


@given(n=st.integers(1, 10000), pref=st.integers(1, 512))
@settings(max_examples=200, deadline=None)
def test_choose_block_divides(n, pref):
    b = choose_block(n, pref)
    assert 1 <= b <= min(n, pref)
    assert n % b == 0


def test_choose_block_rejects_nonpositive():
    with pytest.raises(ValueError):
        choose_block(0, 8)
