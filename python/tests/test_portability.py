"""Portability properties of the AOT artifacts.

The Rust runtime embeds a CPU-only PJRT client (xla_extension 0.5.1): the
HLO it receives must contain no Mosaic/TPU custom-calls (which only a TPU
plugin can execute) and no 64-bit-id serialized-proto constructs. These
tests pin the properties that make the interchange work at all.
"""

import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import pytest

from compile import aot

ARTIFACTS = os.path.join(os.path.dirname(__file__), "../../artifacts")


@pytest.mark.parametrize("name,params", aot.VARIANTS)
def test_lowered_hlo_has_no_custom_calls(name, params):
    # interpret=True must lower Pallas to plain HLO ops; a custom-call
    # would mean a Mosaic kernel leaked through and the Rust CPU client
    # cannot run it.
    text, _ = aot.lower_variant(name, params)
    assert "custom-call" not in text, f"{name} {params} contains a custom-call"
    assert text.startswith("HloModule")


def test_variants_cover_benchmark_set_sizes():
    # The Rust exp::benchmark_set() sizes must all have artifacts so the
    # coordinator can execute the fig7/fig8 workloads functionally.
    ids = {aot.variant_id(n, p) for n, p in aot.VARIANTS}
    for required in [
        "axpy_n1024",
        "montecarlo_n16384",
        "matmul_k16_m16_n16",
        "atax_m64_n64",
        "covariance_m32_n64",
        "bfs_n64",
    ]:
        assert required in ids, f"missing benchmark-set artifact {required}"


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built",
)
def test_built_artifacts_match_variant_list():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    built = {e["id"] for e in manifest["artifacts"]}
    declared = {aot.variant_id(n, p) for n, p in aot.VARIANTS}
    assert built == declared, f"stale artifacts: {built ^ declared}"


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built",
)
def test_built_hlo_files_are_custom_call_free():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    for e in manifest["artifacts"]:
        text = open(os.path.join(ARTIFACTS, e["file"])).read()
        assert "custom-call" not in text, e["id"]
