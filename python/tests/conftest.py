# Make `from compile import ...` work regardless of the pytest invocation
# directory (the Makefile runs from python/, the top-level validation run
# from the repo root).
import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
