"""AOT pipeline tests: HLO text emission, manifest schema, determinism."""

import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import pytest

from compile import aot


def test_variant_id_stable():
    assert aot.variant_id("axpy", {"n": 1024}) == "axpy_n1024"
    assert (
        aot.variant_id("matmul", {"m": 64, "n": 64, "k": 64})
        == "matmul_k64_m64_n64"
    )


def test_lower_variant_axpy():
    text, entry = aot.lower_variant("axpy", {"n": 256})
    assert "HloModule" in text
    assert entry["kernel"] == "axpy"
    assert entry["inputs"][0] == {"shape": [], "dtype": "f64"}
    assert entry["inputs"][1] == {"shape": [256], "dtype": "f64"}
    assert entry["outputs"] == [{"shape": [256], "dtype": "f64"}]


def test_lower_variant_deterministic():
    t1, _ = aot.lower_variant("axpy", {"n": 256})
    t2, _ = aot.lower_variant("axpy", {"n": 256})
    assert t1 == t2


def test_lower_variant_bfs_outputs_i32():
    _, entry = aot.lower_variant("bfs", {"n": 64})
    assert entry["outputs"] == [{"shape": [64], "dtype": "i32"}]


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
def test_manifest_consistent_with_files():
    adir = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(adir, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["format"] == "hlo-text"
    assert len(manifest["artifacts"]) >= 6
    kernels = {e["kernel"] for e in manifest["artifacts"]}
    assert kernels == {"axpy", "matmul", "atax", "covariance", "montecarlo", "bfs"}
    for e in manifest["artifacts"]:
        path = os.path.join(adir, e["file"])
        assert os.path.exists(path), e["file"]
        head = open(path).read(200)
        assert "HloModule" in head
