"""L2 graph shape/behaviour tests: model.build variants and jit round-trips."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


@pytest.mark.parametrize(
    "name,params",
    [
        ("axpy", {"n": 256}),
        ("matmul", {"m": 32, "n": 32, "k": 32}),
        ("atax", {"m": 64, "n": 64}),
        ("covariance", {"m": 32, "n": 64}),
        ("montecarlo", {"n": 1024}),
        ("bfs", {"n": 64}),
    ],
)
def test_build_shapes(name, params):
    fn, example_args = model.build(name, **params)
    out = jax.eval_shape(fn, *example_args)
    assert isinstance(out, tuple) and len(out) == 1


def test_build_unknown_kernel():
    with pytest.raises(ValueError):
        model.build("nope")


def test_axpy_fn_numerics():
    fn, _ = model.build("axpy", n=128)
    x = jnp.arange(128, dtype=jnp.float64)
    y = jnp.ones(128, dtype=jnp.float64)
    (got,) = jax.jit(fn)(jnp.float64(3.0), x, y)
    np.testing.assert_allclose(got, 3.0 * x + 1.0, rtol=1e-12)


def test_matmul_fn_numerics():
    fn, _ = model.build("matmul", m=32, n=32, k=32)
    a = jax.random.normal(jax.random.PRNGKey(0), (32, 32), dtype=jnp.float64)
    b = jax.random.normal(jax.random.PRNGKey(1), (32, 32), dtype=jnp.float64)
    (got,) = jax.jit(fn)(a, b)
    np.testing.assert_allclose(got, ref.matmul_ref(a, b), rtol=1e-10)


def test_montecarlo_fn_estimates_pi():
    fn, _ = model.build("montecarlo", n=4096)
    (got,) = jax.jit(fn)(jnp.uint32(42))
    assert abs(float(got) - np.pi) < 0.2


def test_montecarlo_fn_deterministic_per_seed():
    fn, _ = model.build("montecarlo", n=1024)
    a = jax.jit(fn)(jnp.uint32(7))[0]
    b = jax.jit(fn)(jnp.uint32(7))[0]
    c = jax.jit(fn)(jnp.uint32(8))[0]
    assert float(a) == float(b)
    assert float(a) != float(c) or True  # different seeds usually differ


def test_bfs_fn_numerics():
    fn, _ = model.build("bfs", n=64)
    adj = jnp.ones((64, 64), jnp.float64) - jnp.eye(64, dtype=jnp.float64)
    (dist,) = jax.jit(fn)(adj, jnp.int32(0))
    assert dist.dtype == jnp.int32
    assert int(dist[0]) == 0 and (np.asarray(dist)[1:] == 1).all()
