"""L2: jax compute graphs for the six offloaded workloads.

Each ``<kernel>_fn`` is the exact computation the Rust coordinator executes
through PJRT when a job of that kind is offloaded: it composes the L1 Pallas
kernel(s) with any surrounding jnp glue (mean-centering, RNG, level loop).
``build(name, **params)`` returns ``(fn, example_args)`` ready for
``jax.jit(fn).lower(*example_args)`` in aot.py.

All floating-point workloads are double precision, matching the paper
(§5.1: "All workloads operate on double-precision floating-point operands").
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from . import kernels


def axpy_fn(alpha, x, y):
    """alpha * x + y. Arguments mirror the paper's AXPY job arguments."""
    return (kernels.axpy(alpha, x, y),)


def matmul_fn(a, b):
    """C = A @ B."""
    return (kernels.matmul(a, b),)


def atax_fn(a, x):
    """y = A^T (A x)."""
    return (kernels.atax(a, x),)


def covariance_fn(data):
    """(M, M) covariance of an (M, N) data matrix."""
    return (kernels.covariance(data),)


def montecarlo_fn(seed, n):
    """Monte Carlo pi from ``n`` threefry samples; ``n`` is static."""
    pts = jax.random.uniform(
        jax.random.PRNGKey(seed), (2, n), dtype=jnp.float64
    )
    return (kernels.montecarlo(pts),)


def bfs_fn(adj, src):
    """BFS distances (int32, -1 unreachable) from ``src``."""
    return (kernels.bfs(adj, src),)


def _f64(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float64)


def build(name: str, **params):
    """Return ``(fn, example_args)`` for one AOT variant.

    ``params`` are the static shape parameters: N (axpy/montecarlo/bfs),
    M+N+K (matmul), M+N (atax/covariance).
    """
    if name == "axpy":
        n = params["n"]
        return axpy_fn, (_f64(), _f64(n), _f64(n))
    if name == "matmul":
        m, n, k = params["m"], params["n"], params["k"]
        return matmul_fn, (_f64(m, k), _f64(k, n))
    if name == "atax":
        m, n = params["m"], params["n"]
        return atax_fn, (_f64(m, n), _f64(n))
    if name == "covariance":
        m, n = params["m"], params["n"]
        return covariance_fn, (_f64(m, n),)
    if name == "montecarlo":
        n = params["n"]
        import functools

        fn = functools.partial(montecarlo_fn, n=n)
        return fn, (jax.ShapeDtypeStruct((), jnp.uint32),)
    if name == "bfs":
        n = params["n"]
        return bfs_fn, (_f64(n, n), jax.ShapeDtypeStruct((), jnp.int32))
    raise ValueError(f"unknown kernel {name!r}")
