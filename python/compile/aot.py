"""AOT pipeline: lower every kernel variant to HLO text + manifest.

Interchange format is HLO *text* (NOT ``.serialize()``): jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla_extension 0.5.1
embedded by the Rust ``xla`` crate rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. Lowering goes
through stablehlo -> XlaComputation with ``return_tuple=True`` so the Rust
side unwraps a 1-tuple (``to_tuple1``).

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile's
``make artifacts`` target). Python never runs on the request path: the Rust
binary only reads the files this script produces.
"""

import argparse
import hashlib
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# One entry per artifact. The Rust coordinator picks the variant whose
# static shape matches the job; sizes cover the paper's experiments
# (fig. 9-12 sweeps) at simulator-friendly scale.
VARIANTS = [
    ("axpy", {"n": 256}),
    ("axpy", {"n": 512}),
    ("axpy", {"n": 1024}),
    ("axpy", {"n": 2048}),
    ("axpy", {"n": 4096}),
    ("matmul", {"m": 16, "n": 16, "k": 16}),
    ("matmul", {"m": 32, "n": 32, "k": 32}),
    ("matmul", {"m": 64, "n": 64, "k": 64}),
    ("matmul", {"m": 128, "n": 128, "k": 128}),
    ("atax", {"m": 64, "n": 64}),
    ("atax", {"m": 128, "n": 128}),
    ("atax", {"m": 256, "n": 256}),
    ("covariance", {"m": 32, "n": 64}),
    ("covariance", {"m": 64, "n": 128}),
    ("montecarlo", {"n": 1024}),
    ("montecarlo", {"n": 4096}),
    ("montecarlo", {"n": 16384}),
    ("bfs", {"n": 64}),
    ("bfs", {"n": 128}),
]

_DTYPE_NAMES = {
    jnp.dtype("float64"): "f64",
    jnp.dtype("float32"): "f32",
    jnp.dtype("int32"): "i32",
    jnp.dtype("uint32"): "u32",
}


def variant_id(name: str, params: dict) -> str:
    tags = "_".join(f"{k}{v}" for k, v in sorted(params.items()))
    return f"{name}_{tags}"


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def describe(avals) -> list:
    out = []
    for a in avals:
        out.append({"shape": list(a.shape), "dtype": _DTYPE_NAMES[jnp.dtype(a.dtype)]})
    return out


def lower_variant(name: str, params: dict):
    fn, example_args = model.build(name, **params)
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    out_avals = jax.eval_shape(fn, *example_args)
    entry = {
        "kernel": name,
        "id": variant_id(name, params),
        "params": params,
        "inputs": describe(example_args),
        "outputs": describe(out_avals),
    }
    return text, entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated kernel filter")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {"format": "hlo-text", "artifacts": []}
    for name, params in VARIANTS:
        if only and name not in only:
            continue
        vid = variant_id(name, params)
        text, entry = lower_variant(name, params)
        fname = f"{vid}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        entry["file"] = fname
        entry["sha256"] = hashlib.sha256(text.encode()).hexdigest()
        manifest["artifacts"].append(entry)
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest.json")


if __name__ == "__main__":
    main()
