"""Matmul Pallas kernel: C = A @ B (BLAS level 3, paper §5.1).

2-D output grid with a K-accumulation loop carried across the innermost
grid dimension; each (i, j) block is the tile a Snitch cluster would hold
in TCDM (on TPU: a VMEM tile feeding the MXU). Accumulation into ``o_ref``
across the k dimension relies on Pallas' sequential-grid semantics.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, MAT_BLOCK, choose_block


def _matmul_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


def matmul(a, b, *, block: int | None = None):
    """Tiled matrix multiply of (M, K) @ (K, N) -> (M, N)."""
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"matmul shape mismatch: {a.shape} @ {b.shape}")
    m, k = a.shape
    _, n = b.shape
    bm = block or choose_block(m, MAT_BLOCK)
    bn = block or choose_block(n, MAT_BLOCK)
    bk = block or choose_block(k, MAT_BLOCK)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=INTERPRET,
    )(a, b)
