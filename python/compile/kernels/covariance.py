"""Covariance Pallas kernel (PolyBench data-mining workload, paper §5.1).

``data`` is (M, N): M variables, N observations. The kernel computes the
(M, M) covariance matrix with the unbiased 1/(N-1) estimator. Centering
(mean subtraction) happens in the L2 jax graph; the Pallas kernel is the
rank-N update Xc @ Xc^T over an output tile grid — the per-cluster output
tiles of the paper's partition.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, MAT_BLOCK, choose_block


def _cov_kernel(xi_ref, xj_ref, o_ref, *, inv_nm1):
    o_ref[...] = (
        jnp.dot(xi_ref[...], xj_ref[...].T, preferred_element_type=o_ref.dtype)
        * inv_nm1
    )


def covariance(data, *, block: int | None = None):
    """Covariance matrix of an (M, N) data matrix."""
    if data.ndim != 2:
        raise ValueError(f"covariance expects a 2-D matrix, got {data.shape}")
    m, n = data.shape
    if n < 2:
        raise ValueError("covariance needs at least 2 observations")
    bm = block or choose_block(m, MAT_BLOCK)
    mean = jnp.mean(data, axis=1, keepdims=True)
    centered = data - mean
    import functools

    kern = functools.partial(_cov_kernel, inv_nm1=1.0 / (n - 1))
    return pl.pallas_call(
        kern,
        grid=(m // bm, m // bm),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, m), data.dtype),
        interpret=INTERPRET,
    )(centered, centered)
