"""BFS Pallas kernel (Graph500-style traversal, paper §5.1).

Frontier-expansion BFS over a dense adjacency matrix: the level loop is a
``lax.fori_loop`` in the L2 graph, and each expansion step (frontier-vector
x adjacency-matrix over the boolean semiring) is a tiled Pallas matvec —
the column-tile grid is the per-cluster partition of the node set.
"""

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .common import INTERPRET, MAT_BLOCK, choose_block


def _frontier_kernel(f_ref, adj_ref, o_ref):
    # reach[j] = sum_i frontier[i] * adj[i, j] over this column tile
    o_ref[...] = jnp.dot(
        f_ref[...], adj_ref[...], preferred_element_type=o_ref.dtype
    )


def _expand(frontier, adj, blk):
    n = adj.shape[0]
    return pl.pallas_call(
        _frontier_kernel,
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((n,), lambda j: (0,)),
            pl.BlockSpec((n, blk), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((n,), adj.dtype),
        interpret=INTERPRET,
    )(frontier, adj)


def bfs(adj, src, *, block: int | None = None, max_levels: int | None = None):
    """Distances from ``src`` over the dense 0/1 adjacency ``adj`` (N, N).

    Returns int32 distances with -1 for unreachable nodes. ``max_levels``
    bounds the level loop (defaults to N, the worst-case diameter).
    """
    if adj.ndim != 2 or adj.shape[0] != adj.shape[1]:
        raise ValueError(f"bfs expects a square adjacency, got {adj.shape}")
    n = adj.shape[0]
    blk = block or choose_block(n, MAT_BLOCK)
    levels = max_levels or n
    src = jnp.asarray(src, dtype=jnp.int32)
    dist = jnp.full((n,), -1, dtype=jnp.int32).at[src].set(0)
    frontier = jnp.zeros((n,), dtype=adj.dtype).at[src].set(1)

    def body(level, state):
        dist, frontier = state
        reach = _expand(frontier, adj, blk)
        nxt = jnp.where((reach > 0) & (dist < 0), 1, 0).astype(adj.dtype)
        dist = jnp.where(nxt > 0, level + 1, dist)
        return dist, nxt

    dist, _ = lax.fori_loop(0, levels, body, (dist, frontier))
    return dist
