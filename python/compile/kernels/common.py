"""Shared helpers for the Pallas kernels.

All kernels in this package lower with ``interpret=True``: the CPU PJRT
client (the one the Rust runtime embeds) cannot execute Mosaic custom-calls,
so interpret mode is the correctness path, while the BlockSpec structure
still documents the HBM<->VMEM schedule a real TPU lowering would use. The
grid dimension of every kernel mirrors the per-cluster work partition of the
paper's offload model: one grid block <-> one Snitch cluster's TCDM tile.
"""

import math

INTERPRET = True

# Default tile edge. 128 KiB TCDM / 8 B per f64 / double buffering ~ 8 Ki
# elements per tile; vector kernels use 1-D tiles of this size, matrix
# kernels use square tiles whose footprint stays within the same budget.
VEC_BLOCK = 256
MAT_BLOCK = 32


def choose_block(n: int, preferred: int) -> int:
    """Largest divisor of ``n`` that is <= ``preferred``.

    Pallas grids require the block to divide the dimension; workloads in the
    paper are powers of two so this normally returns ``preferred`` itself.
    """
    if n <= 0:
        raise ValueError(f"dimension must be positive, got {n}")
    b = min(n, preferred)
    while n % b != 0:
        b -= 1
    return b


def cdiv(a: int, b: int) -> int:
    """Ceiling division (grid sizing)."""
    return math.ceil(a / b)
