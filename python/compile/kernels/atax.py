"""ATAX Pallas kernels: y = A^T (A x) (PolyBench, paper §5.1).

Two tiled matvec passes. The row-tile grid of the first pass mirrors the
paper's per-cluster row partition of A; the second pass accumulates the
A^T contribution of each row tile, matching the broadcast communication
pattern the paper identifies as the reason ATAX does not follow Amdahl's
law (every cluster consumes the whole x / produces into the whole y).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, MAT_BLOCK, choose_block


def _matvec_kernel(a_ref, x_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], x_ref[...], preferred_element_type=o_ref.dtype)


def _at_tmp_kernel(a_ref, t_ref, o_ref):
    # Accumulate A[i-tile, :]^T @ tmp[i-tile] into the full-length output.
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...].T, t_ref[...], preferred_element_type=o_ref.dtype
    )


def atax(a, x, *, block: int | None = None):
    """Compute A^T (A x) for A of shape (M, N), x of shape (N,)."""
    if a.ndim != 2 or x.ndim != 1 or a.shape[1] != x.shape[0]:
        raise ValueError(f"atax shape mismatch: {a.shape} vs {x.shape}")
    m, n = a.shape
    bm = block or choose_block(m, MAT_BLOCK)
    tmp = pl.pallas_call(
        _matvec_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), a.dtype),
        interpret=INTERPRET,
    )(a, x)
    return pl.pallas_call(
        _at_tmp_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=INTERPRET,
    )(a, tmp)
