"""AXPY Pallas kernel: alpha * x + y (BLAS level 1, paper §5.1).

The grid partitions the vectors into per-cluster tiles, exactly like the
offload framework distributes contiguous vector chunks to Snitch clusters
(phase E DMA-in, phase F compute, phase G DMA-out). ``alpha`` travels as a
(1, 1) scalar block, the analogue of a job argument in cluster TCDM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, VEC_BLOCK, choose_block


def _axpy_kernel(alpha_ref, x_ref, y_ref, o_ref):
    o_ref[...] = alpha_ref[0] * x_ref[...] + y_ref[...]


def axpy(alpha, x, y, *, block: int | None = None):
    """Compute ``alpha * x + y`` over 1-D vectors with a tiled Pallas kernel.

    Args:
      alpha: scalar (0-D array or python float), promoted to ``x.dtype``.
      x, y: 1-D arrays of equal length.
      block: tile length; defaults to the largest divisor of ``len(x)`` that
        is <= ``VEC_BLOCK``.
    """
    if x.shape != y.shape or x.ndim != 1:
        raise ValueError(f"axpy expects equal 1-D shapes, got {x.shape} / {y.shape}")
    n = x.shape[0]
    blk = block or choose_block(n, VEC_BLOCK)
    alpha_arr = jnp.asarray(alpha, dtype=x.dtype).reshape((1,))
    grid = (n // blk,)
    return pl.pallas_call(
        _axpy_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=INTERPRET,
    )(alpha_arr, x, y)
