# L1: Pallas kernels for the paper's six offloaded workloads (§5.1).
# Every kernel lowers with interpret=True (CPU PJRT path); ref.py holds the
# pure-jnp oracles used by the pytest suite.

from .axpy import axpy
from .matmul import matmul
from .atax import atax
from .covariance import covariance
from .montecarlo import montecarlo
from .bfs import bfs
from . import ref

__all__ = ["axpy", "matmul", "atax", "covariance", "montecarlo", "bfs", "ref"]
