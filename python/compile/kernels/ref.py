"""Pure-jnp correctness oracles for the six offloaded kernels.

These are the ground truth the Pallas kernels (and, transitively, the HLO
artifacts executed by the Rust runtime) are validated against. They mirror
the six workloads of the paper (§5.1): AXPY, Monte Carlo pi, Matmul, ATAX,
Covariance and BFS.
"""

import jax.numpy as jnp
from jax import lax

__all__ = [
    "axpy_ref",
    "matmul_ref",
    "atax_ref",
    "covariance_ref",
    "montecarlo_ref",
    "bfs_ref",
]


def axpy_ref(alpha, x, y):
    """BLAS level-1 AXPY: alpha * x + y."""
    return alpha * x + y


def matmul_ref(a, b):
    """BLAS level-3 GEMM: C = A @ B."""
    return jnp.dot(a, b, preferred_element_type=a.dtype)


def atax_ref(a, x):
    """PolyBench ATAX: y = A^T (A x)."""
    tmp = jnp.dot(a, x, preferred_element_type=a.dtype)
    return jnp.dot(a.T, tmp, preferred_element_type=a.dtype)


def covariance_ref(data):
    """PolyBench Covariance.

    ``data`` is an (M, N) matrix of M variables observed over N samples.
    Returns the (M, M) covariance matrix with the 1/(N-1) estimator.
    """
    n = data.shape[1]
    mean = jnp.mean(data, axis=1, keepdims=True)
    centered = data - mean
    return jnp.dot(centered, centered.T, preferred_element_type=data.dtype) / (n - 1)


def montecarlo_ref(points):
    """Monte Carlo pi estimation.

    ``points`` is a (2, N) array of uniform samples in [0, 1)^2. Returns the
    pi estimate 4 * inside / N as a scalar of the points' dtype.
    """
    x, y = points[0], points[1]
    inside = jnp.sum((x * x + y * y < 1.0).astype(points.dtype))
    return 4.0 * inside / points.shape[1]


def bfs_ref(adj, src):
    """Graph500-style BFS over a dense adjacency matrix.

    ``adj`` is an (N, N) 0/1 matrix (adj[i, j] = 1 iff edge i -> j), ``src``
    a scalar int32 node index. Returns int32 distances, -1 for unreachable.
    """
    n = adj.shape[0]
    dist = jnp.full((n,), -1, dtype=jnp.int32)
    dist = dist.at[src].set(0)
    frontier = jnp.zeros((n,), dtype=adj.dtype).at[src].set(1)

    def body(level, state):
        dist, frontier = state
        # next frontier: nodes reachable from the frontier, not yet visited
        reach = jnp.dot(frontier, adj, preferred_element_type=adj.dtype)
        nxt = jnp.where((reach > 0) & (dist < 0), 1, 0).astype(adj.dtype)
        dist = jnp.where(nxt > 0, level + 1, dist)
        return dist, nxt

    dist, _ = lax.fori_loop(0, n, body, (dist, frontier))
    return dist
