"""Monte Carlo pi Pallas kernel (paper §5.1).

Point generation lives in the L2 jax graph (threefry lowers to plain HLO);
the Pallas kernel is the data-parallel reduction: count samples inside the
unit circle, one partial count per grid block (= per cluster), then a final
jnp reduction. This mirrors the paper's per-cluster partial sums + host
combine structure.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, VEC_BLOCK, choose_block


def _mc_count_kernel(pts_ref, o_ref):
    x = pts_ref[0, :]
    y = pts_ref[1, :]
    o_ref[0] = jnp.sum((x * x + y * y < 1.0).astype(o_ref.dtype))


def montecarlo(points, *, block: int | None = None):
    """Estimate pi from a (2, N) array of uniform [0,1)^2 samples."""
    if points.ndim != 2 or points.shape[0] != 2:
        raise ValueError(f"montecarlo expects (2, N) points, got {points.shape}")
    n = points.shape[1]
    blk = block or choose_block(n, VEC_BLOCK)
    grid = (n // blk,)
    partial = pl.pallas_call(
        _mc_count_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((2, blk), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n // blk,), points.dtype),
        interpret=INTERPRET,
    )(points)
    return 4.0 * jnp.sum(partial) / n
